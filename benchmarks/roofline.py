"""Table 7 analogue (§Roofline): reads results/dryrun.json (compile status +
memory analysis) and results/costs.json (decomposed per-device roofline
terms) and prints the per-cell table."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def run() -> list:
    rows = []
    dry = _load("dryrun.json")
    costs = {(r["arch"], r["shape"], r["mesh"]): r for r in _load("costs.json")}
    ok = sk = er = 0
    for r in dry:
        tag = f"dryrun.{r['arch']}.{r['shape']}.{r['mesh']}"
        if r.get("status") == "ok":
            ok += 1
            mem = r.get("memory", {})
            gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
            note = f"compile {r.get('compile_s')}s; {gb:.1f} GiB/device"
            c = costs.get((r["arch"], r["shape"], r["mesh"]))
            if c and c.get("status") == "ok":
                rl = c["roofline"]
                note += (f"; comp {rl['t_compute_s']:.3g}s mem "
                         f"{rl['t_memory_s']:.3g}s coll "
                         f"{rl['t_collective_s']:.3g}s → {rl['bottleneck']}")
                rows.append((tag, round(rl.get("roofline_fraction") or 0, 4),
                             note))
            else:
                rows.append((tag, "ok", note))
        elif r.get("status") == "skipped":
            sk += 1
            rows.append((tag, "skipped", r.get("reason", "")[:60]))
        else:
            er += 1
            rows.append((tag, "ERROR", r.get("error", "")[:80]))
    rows.append(("dryrun.summary", f"{ok}ok/{sk}skip/{er}err",
                 "see EXPERIMENTS.md §Dry-run / §Roofline"))
    return rows
