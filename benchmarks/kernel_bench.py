"""Kernel micro-benchmarks (CPU host): wall-time of the jnp deployment path
vs the float path, plus the derived TPU-roofline expectation for the Pallas
kernel (interpret mode has no meaningful wall time — the derived column is
the §Roofline-model time on v5e).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.w1a8 import (deploy_w1a8_linear, init_w1a8_linear,
                             w1a8_linear_float_ref, w1a8_linear_infer)

V5E_FLOPS, V5E_BW = 197e12, 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6        # µs


def run() -> list:
    rows = []
    for (m, k, n) in [(256, 4096, 4096), (64, 1152, 128)]:
        key = jax.random.PRNGKey(0)
        p = init_w1a8_linear(key, k, n)
        x = jax.random.uniform(key, (m, k), jnp.float32, 0.0, 2.0)
        d = deploy_w1a8_linear(p)
        a = jnp.clip(jnp.round(x / d["mul_prev"]), 0, 255).astype(jnp.uint8)

        f_ref = jax.jit(lambda p_, x_: w1a8_linear_float_ref(p_, x_))
        f_pkd = jax.jit(lambda d_, a_: w1a8_linear_infer(d_, a_))
        us_ref = _time(f_ref, p, x)
        us_pkd = _time(f_pkd, d, a)
        flops = 2 * m * k * n
        wbytes_bf16 = k * n * 2
        wbytes_packed = k * n / 8
        t_tpu_bf16 = max(flops / V5E_FLOPS, wbytes_bf16 / V5E_BW) * 1e6
        t_tpu_pkd = max(flops / V5E_FLOPS, wbytes_packed / V5E_BW) * 1e6
        rows.append((f"kernel.w1a8_matmul.{m}x{k}x{n}.cpu_ref_us",
                     round(us_ref, 1), "float eval path (CPU wall)"))
        rows.append((f"kernel.w1a8_matmul.{m}x{k}x{n}.cpu_packed_us",
                     round(us_pkd, 1), "1-bit deployed path (CPU wall)"))
        rows.append((f"kernel.w1a8_matmul.{m}x{k}x{n}.v5e_model_us",
                     round(t_tpu_pkd, 2),
                     f"roofline model; bf16-weight equivalent "
                     f"{t_tpu_bf16:.2f}us → {t_tpu_bf16/t_tpu_pkd:.1f}x"))
    return rows
