"""Table 2 analogue: per-layer deployment storage of the detector —
line-buffer bytes (the streaming working set) and packed weight bytes.
Cross-checked against the paper's estimates (10.0KB / 7.5KB buffers etc.).
"""
from __future__ import annotations

from repro.models.yolo import YOLO_LAYERS, spatial_sizes


def run() -> list:
    rows = []
    sizes = spatial_sizes()
    total_w = 0
    for s in YOLO_LAYERS:
        hw = sizes[s.name]
        # streaming line buffers: 2 rows in flight for conv (paper: 2×W×C)
        line_buf = 2 * hw * s.cin
        if s.kind == "w1a8":
            w_bytes = s.ksize ** 2 * s.cin * s.cout // 8       # 1 bit/weight
        else:
            w_bytes = s.ksize ** 2 * s.cin * s.cout * 2        # 16-bit fixed
        total_w += w_bytes
        rows.append((f"storage.{s.name}.line_buffer_kb",
                     round(line_buf / 1024, 2),
                     f"{s.cin}ch × {hw}px × 2 rows"))
        rows.append((f"storage.{s.name}.weights_kb",
                     round(w_bytes / 1024, 2),
                     f"{s.kind} {s.ksize}x{s.ksize} {s.cin}->{s.cout}"))
    rows.append(("storage.total_packed_weights_kb", round(total_w / 1024, 1),
                 "fits the XC7Z020 4.9Mb BRAM budget with room for buffers"))
    return rows
