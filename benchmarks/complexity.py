"""Table 5 analogue: accuracy/complexity claims we can verify offline.

Recomputes the paper's 0.74 M params and 0.098 GFLOPs (its full-precision-op
convention) from the Table-1 structure, plus both alternative conventions.
"""
from __future__ import annotations

from repro.models import yolo

PAPER = {"params_m": 0.74, "gflops": 0.098, "map50": 39.6}


def run() -> list:
    rows = []
    counts = yolo.count_params()
    g = yolo.count_gflops()
    rows.append(("yolo_w1a8.params_total", counts["total"],
                 f"paper 0.74M; rel err "
                 f"{abs(counts['total']/1e6 - PAPER['params_m'])/PAPER['params_m']:.3%}"))
    rows.append(("yolo_w1a8.gflops_paper_conv", round(g["paper_gflops"], 5),
                 f"paper 0.098; rel err "
                 f"{abs(g['paper_gflops'] - PAPER['gflops'])/PAPER['gflops']:.3%}"))
    rows.append(("yolo_w1a8.gflops_total", round(g["total_gflops"], 4),
                 "binary MACs at face value"))
    rows.append(("yolo_w1a8.gflops_binary_div64", round(
        g["binary_discount64_gflops"], 4), "XNOR-discount convention"))
    rows.append(("yolo_w1a8.map50_note", "n/a",
                 "VOC2007 unavailable offline; mAP untestable — structural "
                 "claims above verified instead"))
    return rows
