"""Table 6 analogue: layer-wise numerical alignment of the deployed integer
datapath ("RTL" role) and Pallas kernel path against the float oracle
("ONNX Runtime" role), at the same four checkpoints as the paper:
Conv1 raw, Conv1 post, Conv2 post, final raw head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core import verify
from repro.core.quant import ACT_QMAX, round_half_away
from repro.models import yolo


def _intermediates_float(params, img):
    """Float-oracle intermediates at the paper's verification points."""
    outs = {}
    x = img
    p1 = params["conv1"]
    w = fxp.CONV1_W.roundtrip(p1["w"])
    b = fxp.CONV1_B.roundtrip(p1["b"])
    conv1_raw = yolo._conv2d(x, w) + b
    outs["conv1_raw"] = conv1_raw
    act = jax.nn.relu(conv1_raw)
    act = yolo._maxpool2(act)
    s2 = jnp.broadcast_to(params["conv2"]["act_step"], (16,))
    outs["conv1_post"] = jnp.clip(round_half_away(act / s2), 0, ACT_QMAX)
    return outs


def _intermediates_int(art, img_u8):
    outs = {}
    x = np.asarray(img_u8, np.int64)
    entry = art["layers"][0]
    cols = yolo._im2col_np(x, 3)
    wf = entry["w_raw"].reshape(-1, 16)
    acc = cols @ wf + (entry["b_raw"] << 5)
    outs["conv1_raw"] = acc / 2.0 ** 19          # paper: DUT / 2^19
    acc = np.maximum(acc, 0)
    q = yolo._rshift_round(acc * entry["post_mult"], entry["post_shift"])
    q = np.clip(q, 0, ACT_QMAX)
    b, h, w_, c = q.shape
    outs["conv1_post"] = q.reshape(b, h // 2, 2, w_ // 2, 2, c).max(axis=(2, 4))
    return outs


def run(trained_params=None) -> list:
    key = jax.random.PRNGKey(42)
    params = trained_params or yolo.init_yolo_params(key)
    img_u8 = jax.random.randint(jax.random.PRNGKey(1), (1, 320, 320, 3),
                                0, 256, jnp.int32).astype(jnp.uint8)
    img = img_u8.astype(jnp.float32) / 256.0
    if trained_params is None:
        params = yolo.calibrate_yolo(params, img)

    f = _intermediates_float(params, img)
    art = yolo.deploy_yolo(params)
    i = _intermediates_int(art, np.asarray(img_u8))

    rows = []
    r = verify.compare("conv1_raw", i["conv1_raw"],
                       np.asarray(f["conv1_raw"], np.float64), lsb=2 ** -19)
    rows.append(("align.conv1_raw.corr", round(r.corr, 6),
                 f"paper corr 0.999999; max_abs={r.max_abs:.3g}"))
    # conv1 post is pre-pool in the paper; we compare post-pool (equivalent
    # ordering for max+monotone quant) in 8-bit codes, 1-LSB statistic
    r = verify.compare("conv1_post", i["conv1_post"],
                       np.asarray(f["conv1_post"], np.float64), lsb=1.0)
    rows.append(("align.conv1_post.within_1lsb",
                 round(100 * r.within_1lsb, 4),
                 f"paper 98.81%; mean_abs={r.mean_abs:.4f} LSB"))

    out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                       np.float64)
    out_i = yolo.yolo_forward_int(art, np.asarray(img_u8)) / 2.0 ** 15
    r = verify.compare("final_raw", out_i, out_f, lsb=0.02)
    rows.append(("align.final_raw.corr", round(r.corr, 6),
                 f"paper corr 0.999964 (trained); max_abs={r.max_abs:.4g} "
                 f"(paper 0.109), mean_abs={r.mean_abs:.4g} (paper 0.020)"))

    kart = yolo.deploy_yolo_kernel(params)
    out_k = np.asarray(yolo.yolo_forward_kernel(kart, img, interpret=True),
                       np.float64)
    r = verify.compare("final_raw_kernel", out_k, out_f, lsb=0.02)
    rows.append(("align.final_raw_kernel.corr", round(r.corr, 6),
                 f"Pallas path vs float oracle; max_abs={r.max_abs:.4g}"))
    return rows
