"""Benchmark harness — one module per paper table. Prints
``name,value,notes`` CSV. Usage: PYTHONPATH=src python -m benchmarks.run
[--only complexity|alignment|memory|kernels|roofline]"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (alignment, complexity, kernel_bench,
                            memory_table, roofline)
    suites = {
        "complexity": complexity.run,      # Table 5
        "memory": memory_table.run,        # Table 2
        "alignment": alignment.run,        # Table 6
        "kernels": kernel_bench.run,       # kernel micro/model bench
        "roofline": roofline.run,          # Table 7 analogue (§Roofline)
    }
    print("name,value,notes")
    failures = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                tag, value, note = row
                print(f"{tag},{value},\"{note}\"")
        except Exception as e:                              # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,{type(e).__name__},\"{e}\"")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
