"""Serving example: continuous batching with 1-bit packed W1A8 weights.

Five requests share three slots; the engine prefills each prompt into a free
slot and decodes all active rows in one fused step per tick.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch granite-20b]
"""
import argparse
import time

import jax

from repro import configs
from repro.models.transformer import init_lm_params
from repro.serve import ServeEngine, deploy_lm, packed_param_bytes
from repro.serve.batching import Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-20b")
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
params = init_lm_params(jax.random.PRNGKey(0), cfg)
packed = deploy_lm(params)
acct = packed_param_bytes(packed)
print(f"deployed {args.arch} (reduced): {acct['packed_bytes']/1e6:.2f} MB "
      f"packed ({acct['ratio']:.1f}x smaller than bf16)")

eng = ServeEngine(cfg, packed, slots=3, max_len=64, mode="w1a8_eval")
reqs = [Request(rid=i, prompt=[5 + i, 23, 7, 11 + i], max_new=args.max_new)
        for i in range(5)]
t0 = time.time()
eng.run(list(reqs))
dt = time.time() - t0
tok = sum(len(r.out) for r in reqs)
print(f"served {len(reqs)} requests / {tok} tokens in {dt:.1f}s "
      f"({tok/dt:.1f} tok/s on 1 CPU core)")
for r in reqs:
    print(f"  req {r.rid}: prompt {r.prompt} → {r.out}")
