"""Serving example (serve v2): continuous batching with 1-bit packed W1A8
weights through the backend-agnostic Scheduler.

Five requests share three slots; the scheduler prefills arrivals as one
batch per prompt length and decodes all active rows in one fused step per
tick. Per-request sampling: req 4 samples at temperature 0.8 and stops on
token 9 while the others decode greedily.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch granite-20b]
"""
import argparse
import time

import jax

from repro import configs
from repro.models.transformer import init_lm_params
from repro.serve import (LMBackend, SamplingParams, Scheduler, ServeRequest,
                         deploy_lm, packed_param_bytes)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-20b")
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
params = init_lm_params(jax.random.PRNGKey(0), cfg)
packed = deploy_lm(params)
acct = packed_param_bytes(packed)
print(f"deployed {args.arch} (reduced): {acct['packed_bytes']/1e6:.2f} MB "
      f"packed ({acct['ratio']:.1f}x smaller than bf16)")

sched = Scheduler(LMBackend(cfg, packed, slots=3, max_len=64,
                            mode="w1a8_eval"))
reqs = [ServeRequest(rid=i, prompt=[5 + i, 23, 7, 11 + i],
                     sampling=SamplingParams(
                         max_new=args.max_new,
                         temperature=0.8 if i == 4 else 0.0,
                         stop_tokens=(9,) if i == 4 else ()))
        for i in range(5)]
t0 = time.time()
results = sched.run(reqs)
dt = time.time() - t0
s = sched.metrics.summary()
print(f"served {len(results)} requests / {s['tokens']} tokens in {dt:.1f}s "
      f"({s['tokens']/dt:.1f} tok/s on 1 CPU core, "
      f"occupancy {s['batch_occupancy']:.2f})")
for r in sorted(results, key=lambda r: r.rid):
    print(f"  req {r.rid} [{r.finish_reason}]: → {r.tokens}")
