"""End-to-end driver (the paper's pipeline): QAT-train the W1A8 detector on
the synthetic detection set, deploy to the integer datapath, verify
alignment (Table 6 analogue), and run decode+NMS on a test image.

Run: PYTHONPATH=src python examples/train_yolo_qat.py [--steps 60]
(~2 s/step on CPU; a few hundred steps reproduce the full workflow.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verify
from repro.data import pipeline as data
from repro.models import detection, yolo
from repro.optim import adamw
from repro.train.yolo_qat import make_yolo_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch", type=int, default=2)
args = ap.parse_args()

ds = data.make_detection_dataset(args.batch)
img0, _, _ = data.detection_batch(ds, 0)
params = yolo.calibrate_yolo(yolo.init_yolo_params(jax.random.PRNGKey(0)),
                             img0)
opt = adamw(1e-3)
step = make_yolo_train_step(opt)
state = opt[0](params)

print(f"QAT training the W1A8 detector ({args.steps} steps)…")
t0 = time.time()
for i in range(args.steps):
    img, boxes, classes = data.detection_batch(ds, i)
    params, state, m = step(params, state, img, boxes, classes)
    if i % 10 == 0 or i == args.steps - 1:
        print(f"  step {i:3d} loss {float(m['loss']):8.4f}")
print(f"trained in {time.time()-t0:.0f}s")

print("\nparameter extraction → fixed point → integer datapath (§4)…")
art = yolo.deploy_yolo(params)
img, boxes, classes = data.detection_batch(ds, 9999)
img_u8 = jnp.clip(jnp.round(img * 256.0), 0, 255).astype(jnp.uint8)
out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                   np.float64)
out_i = yolo.yolo_forward_int(art, np.asarray(img_u8)) / 2.0 ** 15
rep = verify.compare("final_raw (trained)", out_i, out_f, lsb=0.02)
print(rep.row())
print("paper Table 6 reference: corr=0.999964, mean_abs=0.020027")

print("\ndetection head decode + NMS on the integer output…")
raw = jnp.asarray(out_i, jnp.float32)
b, s, c = detection.postprocess(raw, score_thresh=0.05, max_out=8)
kept = int(jnp.sum(s[0] > 0))
print(f"{kept} boxes after NMS; ground truth had "
      f"{int(jnp.sum(classes[0] >= 0))}")
for j in range(min(kept, 4)):
    print(f"  box cxcywh={np.round(np.asarray(b[0, j]), 3)} "
          f"score={float(s[0, j]):.3f} class={int(c[0, j])}")
print("\ne2e OK")
