"""Quickstart: the W1A8 engine in five minutes.

  1. a W1A8 linear layer — QAT training view vs deployed 1-bit view,
  2. the paper's detector — params/GFLOPs claims + integer-exact inference,
  3. an LM architecture with the W1A8 body (reduced config, CPU).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verify, w1a8
from repro.core.quant import quantize_act
from repro.models import yolo
from repro import configs
from repro.models.transformer import init_lm_params, lm_forward

print("=== 1. W1A8 linear: train vs deployed-1-bit ===")
key = jax.random.PRNGKey(0)
p = w1a8.init_w1a8_linear(key, 256, 128)
x = jax.random.uniform(jax.random.PRNGKey(1), (4, 256), maxval=2.0)
y_train = w1a8.w1a8_linear_train(p, x)            # QAT (STE + LSQ)
d = w1a8.deploy_w1a8_linear(p)                    # pack to 1 bit/weight
a = quantize_act(x, p["act_step"]).astype(jnp.uint8)
y_dep = w1a8.w1a8_linear_infer(d, a)              # Eq. 3-4 datapath
print(verify.compare("linear train-vs-deployed", np.asarray(y_dep),
                     np.asarray(y_train), lsb=0.05).row())
print(f"weight storage: {d['w_packed'].nbytes} B packed vs "
      f"{p['w'].nbytes} B latent f32 ({p['w'].nbytes/d['w_packed'].nbytes:.0f}x)")

print("\n=== 2. Paper detector: structure claims + integer pipeline ===")
print("params:", yolo.count_params(), "(paper: 0.74 M)")
print("gflops:", {k: round(v, 4) for k, v in yolo.count_gflops().items()},
      "(paper: 0.098)")
params = yolo.init_yolo_params(jax.random.PRNGKey(42))
img_u8 = jax.random.randint(jax.random.PRNGKey(2), (1, 320, 320, 3), 0, 256,
                            jnp.int32).astype(jnp.uint8)
img = img_u8.astype(jnp.float32) / 256.0
params = yolo.calibrate_yolo(params, img)
art = yolo.deploy_yolo(params)                    # COE-analogue artifact
out_int = yolo.yolo_forward_int(art, np.asarray(img_u8)) / 2.0 ** 15
out_f = np.asarray(yolo.yolo_forward_float(params, img), np.float64)
print(verify.compare("detector int-vs-float", out_int, out_f, lsb=0.02).row())

print("\n=== 3. W1A8 LM (mixtral-8x7b reduced) ===")
cfg = configs.get_reduced("mixtral-8x7b")
lm = init_lm_params(jax.random.PRNGKey(3), cfg)
toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab_size,
                          jnp.int32)
logits = lm_forward(cfg, lm, toks, mode="w1a8_eval")
print("logits:", logits.shape, "finite:", bool(jnp.all(jnp.isfinite(logits))))
print("\nquickstart OK")
