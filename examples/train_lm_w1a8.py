"""LM QAT training with checkpoint/restart — the fault-tolerance loop.

Trains a reduced W1A8 LM, simulates a preemption mid-run, then resumes from
the checkpoint and finishes (loss continues from where it left off).

Run: PYTHONPATH=src python examples/train_lm_w1a8.py [--arch chatglm3-6b]
"""
import argparse
import os
import tempfile

import jax

from repro import ckpt as ckpt_lib
from repro import configs
from repro.data import pipeline as data
from repro.models.transformer import init_lm_params
from repro.optim import adamw
from repro.optim.schedules import cosine_schedule
from repro.train.loop import run_train
from repro.train.step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="chatglm3-6b")
ap.add_argument("--steps", type=int, default=40)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
opt = adamw(cosine_schedule(3e-3, 4, args.steps))
step_fn = jax.jit(make_train_step(cfg, opt, remat=False, microbatches=2))
ds = data.make_lm_dataset(cfg.vocab_size, 16, 8)


def batch_fn(i):
    t, l = data.lm_batch(ds, i)
    return {"tokens": t, "labels": l}


ckpt_dir = os.path.join(tempfile.mkdtemp(), "ckpt")
params = init_lm_params(jax.random.PRNGKey(0), cfg)
state = opt[0](params)

half = args.steps // 2
print(f"phase 1: train to step {half}, then 'preempt'…")
params, state, n = run_train(train_step=step_fn, params=params,
                             opt_state=state, batch_fn=batch_fn, steps=half,
                             ckpt_dir=ckpt_dir, ckpt_every=10,
                             async_ckpt=True)
last = ckpt_lib.latest_step(ckpt_dir)
print(f"checkpointed at step {last}; simulating restart…")

template = {"params": params, "opt_state": state}
restored, meta = ckpt_lib.restore_checkpoint(ckpt_dir, last, template)
print(f"phase 2: resume from step {last} (ckpt loss "
      f"{meta.get('loss', float('nan')):.4f}) → {args.steps}")
run_train(train_step=step_fn, params=restored["params"],
          opt_state=restored["opt_state"], batch_fn=batch_fn,
          steps=args.steps, start_step=last, ckpt_dir=ckpt_dir,
          ckpt_every=10)
print("restart e2e OK")
