"""Property-based tests (hypothesis) on the system's invariants.

hypothesis is an optional dev dependency (declared in pyproject's ``dev``
extra); when absent the whole module skips instead of erroring collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
hnp = pytest.importorskip("hypothesis.extra.numpy")

from repro.core import fixedpoint as fxp
from repro.core import packing
from repro.core.quant import (ACT_QMAX, binarize_weight, quantize_act,
                              round_half_away, sign_accumulate_fused)

SET = dict(deadline=None, max_examples=25)


@settings(**SET)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=80),
                  elements=st.floats(-4, 4, width=32,
                                     allow_subnormal=False)))
def test_pack_unpack_roundtrip(w):
    pk = packing.pack_signs(jnp.asarray(w), axis=0)
    un = np.asarray(packing.unpack_signs(pk, w.shape[0], axis=0))
    assert np.array_equal(un, np.where(w >= 0, 1, -1))
    # storage: exactly ceil(K/32) words per column
    assert pk.shape == ((w.shape[0] + 31) // 32, w.shape[1])


@settings(**SET)
@given(hnp.arrays(np.float32, (13,), elements=st.floats(-1e4, 1e4,
                                                        width=32)))
def test_round_half_away_matches_python(x):
    got = np.asarray(round_half_away(jnp.asarray(x)))
    import math
    want = np.asarray([math.floor(v + 0.5) if v >= 0 else math.ceil(v - 0.5)
                       for v in x], np.float32)
    assert np.array_equal(got, want)


@settings(**SET)
@given(hnp.arrays(np.float32, (4, 7), elements=st.floats(-100, 100,
                                                         width=32)),
       st.floats(1e-3, 2.0))
def test_quantize_act_bounds_and_idempotence(x, step):
    q = np.asarray(quantize_act(jnp.asarray(x), jnp.float32(step)))
    assert q.min() >= 0 and q.max() <= ACT_QMAX
    assert np.array_equal(q, np.round(q))            # integer codes
    # quantizing a dequantized value is a fixed point
    q2 = np.asarray(quantize_act(jnp.asarray(q * step), jnp.float32(step)))
    assert np.array_equal(q, q2)


@settings(**SET)
@given(st.integers(0, 2 ** 40), st.integers(1, 2 ** 16), st.integers(4, 20))
def test_fixed_mul_rshift_is_rounded_product(x, m, f):
    got = int(fxp.fixed_mul_rshift(np.int64(x), np.int64(m), f))
    want = int(np.floor(x * m / 2 ** f + 0.5))
    assert got == want


@settings(**SET)
@given(st.floats(-30, 30, width=32))
def test_qformat_roundtrip_error_bound(v):
    qf = fxp.CONV1_W                                  # Q5.11
    rt = float(qf.roundtrip(jnp.float32(v)))
    if -32 <= v <= 31.999:                            # in range
        assert abs(rt - v) <= 2 ** -11 / 2 + 1e-9
    assert qf.raw_min / qf.scale <= rt <= qf.raw_max / qf.scale


@settings(**SET)
@given(hnp.arrays(np.float32, (3, 24), elements=st.floats(0, 255, width=32)),
       hnp.arrays(np.float32, (24, 8), elements=st.floats(-2, 2, width=32)),
       hnp.arrays(np.float32, (24,), elements=st.floats(0.0078125, 1.0,
                                                        width=32)))
def test_eq34_fusion_equals_two_step(a, w, m):
    """Eq. 3-4: Σ s(m·a) == (a ⊙ m) @ sign(w) — fusion is exact algebra."""
    signs = binarize_weight(jnp.asarray(w))
    fused = np.asarray(sign_accumulate_fused(jnp.asarray(a), jnp.asarray(m),
                                             signs))
    # numpy accumulates in f64; tolerate f32 summation-order differences
    twostep = np.asarray((a * m) @ np.asarray(signs))
    scale = np.abs(twostep).max() + 1.0
    np.testing.assert_allclose(fused, twostep, atol=2e-5 * scale)


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 3), st.integers(8, 40), st.integers(1, 2),
       st.integers(0, 1000))
def test_blockwise_attention_equals_dense(b, s, kvh_pow, seed):
    from repro.models.layers import _blockwise_attention, _attn_weights
    kv = 2 * kvh_pow
    h, hd = kv * 2, 8
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    probs, g = _attn_weights(q, k, causal=True, window=0, softcap=0.0,
                             q_pos=pos, k_pos=pos)
    dense = jnp.einsum("bkgst,btkd->bskgd", probs, v).reshape(b, s, h, hd)
    block = _blockwise_attention(q, k, v, causal=True, window=0, softcap=0.0,
                                 q_pos=pos, k_pos=pos, block=16)
    np.testing.assert_allclose(np.asarray(block), np.asarray(dense),
                               atol=2e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(4, 32), st.integers(0, 100))
def test_moe_no_drop_when_cf_equals_experts(t, seed):
    """cap ≥ T·k ⇒ every assignment survives ⇒ Σ gates recovered exactly."""
    from repro.models.layers import ModelConfig
    from repro.models import moe as moe_mod
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=8,
                      num_experts=4, top_k=2, capacity_factor=4.0,
                      w1a8_body=False)
    p = moe_mod.init_moe(jax.random.PRNGKey(seed), cfg)
    # identity-ish experts: y should equal Σ_k gate_k · expert_k(x)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, 16))
    y = moe_mod.moe_ffn(p, cfg, x, mode="float")
    # brute-force reference over all experts
    import numpy as _np
    logits = np.asarray(x @ p["router"])
    top = _np.argsort(-logits, axis=1)[:, :2]
    gates = jax.nn.softmax(jnp.take_along_axis(jnp.asarray(logits),
                                               jnp.asarray(top), 1), -1)
    want = _np.zeros((t, 16), _np.float32)
    for e in range(4):
        up = np.asarray(x @ p["up"][e])
        gt = np.asarray(x @ p["gate"][e])
        h = up * (gt / (1 + _np.exp(-gt)))
        out_e = h @ np.asarray(p["down"][e])
        for kk in range(2):
            mask = (top[:, kk] == e)
            want[mask] += _np.asarray(gates)[mask, kk, None] * out_e[mask]
    _np.testing.assert_allclose(np.asarray(y), want, atol=3e-4)


@settings(deadline=None, max_examples=40)
@given(st.data())
def test_scheduler_trace_fifo_within_deadline_no_slot_leak(data):
    """serve v3 scheduler property: random arrival traces — bursts of 1–4B
    requests, mixed lm/detect lifetimes, deadlines, priority classes,
    bounded queue — must admit (priority, deadline, arrival-seq) order,
    never leak slots, and end with an empty wait queue (checked against the
    pure-python reference model in tests/test_serve_stream.py; a failing
    example's trace is printed in the assertion message, and hypothesis
    shrinks it)."""
    from test_serve_stream import assert_trace_ok
    capacity = data.draw(st.integers(1, 4), label="capacity")
    admit_width = data.draw(st.one_of(st.none(), st.integers(1, capacity)),
                            label="admit_width")
    rid = 0
    trace = []
    for _ in range(data.draw(st.integers(1, 4), label="n_bursts")):
        idle = data.draw(st.integers(0, 2))
        burst = []
        for _ in range(data.draw(st.integers(1, 4 * capacity))):  # 1..4B
            burst.append((rid,
                          data.draw(st.sampled_from(["lm", "detect"])),
                          data.draw(st.integers(1, 3)),        # lifetime
                          data.draw(st.one_of(st.none(),
                                              st.integers(0, 6))),
                          data.draw(st.integers(0, 2))))       # priority
            rid += 1
        trace.append((idle, burst))
    max_queue = data.draw(st.one_of(st.none(),
                                    st.integers(1, 3 * capacity)),
                          label="max_queue")
    assert_trace_ok(capacity, admit_width, trace, max_queue)


@settings(deadline=None, max_examples=30)
@given(st.data())
def test_fleet_router_conserves_requests_and_replays_deterministically(data):
    """Fleet property: random arrival traces through a Router (random
    replica count, queue bound, scripted scale events) — no request lost or
    duplicated (completed + every drop cause = submitted, each rid surfaces
    exactly once), scale-down never strands queued or in-flight work, and
    an identical replay produces the identical result stream (checked
    against the pure-python fleet reference in tests/test_fleet.py)."""
    from test_fleet import assert_fleet_trace_ok
    n_replicas = data.draw(st.integers(1, 3), label="replicas")
    width = data.draw(st.integers(1, 3), label="width")
    service = data.draw(st.integers(1, 3), label="service_ticks")
    max_queue = data.draw(st.one_of(st.none(), st.integers(1, 6)),
                          label="max_queue")
    rid = 0
    trace = []
    for _ in range(data.draw(st.integers(1, 5), label="n_bursts")):
        idle = data.draw(st.integers(0, 3))
        burst = []
        for _ in range(data.draw(st.integers(0, 4 * width))):
            burst.append((rid,
                          data.draw(st.one_of(st.none(),
                                              st.integers(0, 6))),  # dl
                          data.draw(st.integers(0, 2))))            # prio
            rid += 1
        trace.append((idle, burst))
    # scripted scale events: (tick, +1|-1) — exercises drain/retire paths
    scale_script = data.draw(
        st.lists(st.tuples(st.integers(0, 12), st.sampled_from([+1, -1])),
                 max_size=3), label="scale_script")
    assert_fleet_trace_ok(n_replicas, width, service, trace,
                          max_queue=max_queue, scale_script=dict(scale_script))


@settings(deadline=None, max_examples=25)
@given(hnp.arrays(np.float32, (4, 6),
                  elements=st.floats(-4, 4, width=32, allow_subnormal=False)),
       st.sampled_from([1e-6, 1e-3, 1.0, 1e3, 1e6]),
       st.booleans())
def test_int8_wire_permute_roundtrip_within_envelope(x, mag, flip):
    """The pipeline stage wire: quantize → ppermute(int8 codes + f32 scale)
    → dequantize round-trips within the documented envelope |x̂ − x| ≤
    max|x|/254 per element per hop (collectives.permute_quantized), across
    magnitudes and sign mixes including rows that straddle zero; devices
    outside the permutation dequantize to exactly 0 (the f32-ppermute
    boundary semantics the 1F1B schedule relies on)."""
    from repro.dist.collectives import permute_quantized
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (see conftest.py)")
    x = x * np.float32(mag) * (np.float32(-1.0) if flip else np.float32(1.0))
    mesh = jax.make_mesh((4,), ("d",))
    spec = jax.sharding.PartitionSpec("d")
    shift = [(i, i + 1) for i in range(3)]        # ring edge stays dark
    fn = jax.jit(jax.shard_map(lambda s: permute_quantized(s, "d", shift),
                               mesh=mesh, in_specs=spec, out_specs=spec))
    out = np.asarray(fn(jnp.asarray(x)))
    np.testing.assert_array_equal(out[0], 0.0)    # boundary device: exact 0
    for row in range(3):                          # device row → row+1
        envelope = np.abs(x[row]).max() / 254 + 1e-30
        err = np.abs(out[row + 1] - x[row]).max()
        assert err <= envelope * (1 + 1e-6), (row, err, envelope)


@settings(deadline=None, max_examples=25)
@given(hnp.arrays(np.float32, (4, 64),
                  elements=st.floats(-4, 4, width=32, allow_subnormal=False)),
       st.sampled_from([1e-6, 1e-3, 1.0, 1e3, 1e6]),
       st.booleans(), st.booleans())
def test_b1_roundtrip_sign_exact_alpha_clamped(x, mag, flip, per_slice):
    """The b1 activation wire (QTensor.quantize_b1 → dequantize): signs
    survive the round trip exactly (x̂ = sign(x)·α with the x ≥ 0 → +1
    packing convention), |x̂| ≡ α = mean|x| (per tensor, or per row under
    per_slice=True) across six orders of magnitude and global sign flips,
    and an all-zero row — forced into every example — hits the 1e-20 α
    clamp instead of NaN-poisoning the dequantize."""
    from repro.core.qtensor import QTensor
    x = x * np.float32(mag) * (np.float32(-1.0) if flip else np.float32(1.0))
    x[1] = 0.0                                    # guaranteed all-zero row
    qt = QTensor.quantize_b1(jnp.asarray(x), axis=-1, per_slice=per_slice)
    xh = np.asarray(qt.dequantize())
    alpha = np.asarray(qt.scale)
    assert np.all(np.isfinite(xh)) and np.all(alpha >= 1e-20)
    assert np.array_equal(np.sign(xh), np.where(x >= 0, 1.0, -1.0))
    np.testing.assert_array_equal(np.abs(xh), np.broadcast_to(alpha, xh.shape))
    want = np.abs(x).mean(axis=-1, keepdims=True) if per_slice \
        else np.abs(x).mean()
    np.testing.assert_allclose(alpha, np.maximum(want, 1e-20).astype(
        np.float32), rtol=1e-5)
    if per_slice:                                 # the clamp, observably
        assert alpha.reshape(-1)[1] == np.float32(1e-20)
        assert np.abs(xh[1]).max() <= 1e-20


@settings(deadline=None, max_examples=8)
@given(st.integers(2, 12), st.integers(0, 50))
def test_nms_kept_boxes_are_mutually_distant(n, seed):
    from repro.models.detection import iou_cxcywh, nms
    key = jax.random.PRNGKey(seed)
    boxes = jnp.stack([jax.random.uniform(key, (n,), minval=0.2, maxval=0.8),
                       jax.random.uniform(jax.random.fold_in(key, 1), (n,),
                                          minval=0.2, maxval=0.8),
                       jnp.full((n,), 0.2), jnp.full((n,), 0.2)], -1)
    scores = jax.random.uniform(jax.random.fold_in(key, 2), (n, 20),
                                minval=0.3, maxval=1.0)
    ob, osc, oc = nms(boxes, scores, iou_thresh=0.45, max_out=n)
    kept = [(np.asarray(ob[i]), int(oc[i])) for i in range(n)
            if float(osc[i]) > 0]
    for i in range(len(kept)):
        for j in range(i + 1, len(kept)):
            if kept[i][1] == kept[j][1]:
                iou = float(iou_cxcywh(jnp.asarray(kept[i][0]),
                                       jnp.asarray(kept[j][0])))
                assert iou <= 0.45 + 1e-6
