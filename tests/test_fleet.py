"""Fleet tier tests (serve.fleet): Router dispatch / elasticity against a
pure-python fleet reference, Autoscaler hysteresis, FleetMetrics
conservation, completion-deadline and priority semantics, and the
NaN-free-summary regression.

`run_fleet_trace` / `reference_fleet_trace` / `assert_fleet_trace_ok` are
also imported by the fleet hypothesis property in tests/test_properties.py;
keep them dependency-free (no jax in the trace machinery).
"""
import math
import types

import numpy as np

from repro.serve.api import SamplingParams, ServeRequest, ServeResult
from repro.serve.fleet import (Autoscaler, AutoscalerConfig, FleetMetrics,
                               ModelBackend, Router)
from repro.serve.scheduler import Scheduler

_SP = SamplingParams()
_INF = float("inf")


def _req(rid, dl=None, prio=0, cd=None):
    return ServeRequest(rid=rid, sampling=_SP, deadline_ticks=dl,
                        priority=prio, completion_deadline_ticks=cd)


# ---------------------------------------------------------------------------
# Fleet trace property: Router vs a pure-python fleet reference
# ---------------------------------------------------------------------------

class ScriptedScaler:
    """Deterministic Autoscaler stand-in: a {tick: ±1} script — the property
    tests exercise the Router's scale/drain/retire paths without depending
    on watermark tuning."""

    def __init__(self, script):
        self.script = dict(script or {})

    def decide(self, tick, schedulers):
        return self.script.get(tick, 0)


def run_fleet_trace(n_replicas, width, service, trace, *, max_queue=None,
                    scale_script=None):
    """Drive a real Router (ModelBackend replicas) through an arrival trace.

    ``trace`` = [(idle_ticks, burst), ...]; burst = [(rid, deadline_ticks,
    priority), ...]. Returns ([(rid, finish_reason), ...] in result order,
    fleet summary). Asserts drain leaves NO replica — live, draining or
    retired — holding queued or in-flight work."""
    router = Router(lambda: ModelBackend(width, service),
                    replicas=n_replicas, max_queue=max_queue,
                    autoscaler=ScriptedScaler(scale_script),
                    metrics=FleetMetrics(slo_ticks=6), keep_results=True)
    for idle, burst in trace:
        for _ in range(idle):
            router.tick()
        for rid, dl, prio in burst:
            router.submit(_req(rid, dl=dl, prio=prio))
    router.drain(guard=10_000)
    for rep in router.replicas.values():
        assert rep.sched.queued == 0 and not rep.sched.active, \
            f"replica {rep.rid} stranded work after drain"
    for rrid, sched in router.retired.items():
        assert sched.queued == 0 and not sched.active, \
            f"retired replica {rrid} stranded work"
    assert router.metrics.lost == 0, router.metrics.summary()
    return ([(r.rid, r.finish_reason) for r in router.results],
            router.metrics.summary())


def reference_fleet_trace(n_replicas, width, service, trace, *,
                          max_queue=None, scale_script=None):
    """Pure-python fleet oracle with the documented semantics: submit routes
    to the live replica with (least queue depth, most deadline slack,
    lowest id); each replica ticks like the scheduler reference (expire
    overdue in deadline order, admit (priority, deadline, seq) pages of
    ``width``, fixed ``service``-tick rows, completions in slot order);
    scale-down drains the least-loaded live replica, which retires only
    once empty."""
    scale_script = dict(scale_script or {})
    results = []
    reps = {}
    tick_no, next_rid, seq = 0, 0, 0

    def add_replica():
        nonlocal next_rid
        reps[next_rid] = {"waiting": [], "free": list(range(width)),
                          "rows": {}, "draining": False}
        next_rid += 1

    for _ in range(n_replicas):
        add_replica()

    def sched_tick(rep):
        overdue = sorted((w for w in rep["waiting"] if w[1] < tick_no),
                         key=lambda w: (w[1], w[2]))
        for _, _, _, rid in overdue:
            results.append((rid, "expired"))
        rep["waiting"] = sorted(w for w in rep["waiting"] if w[1] >= tick_no)
        admitted = 0
        while rep["waiting"] and rep["free"] and admitted < width:
            _, _, _, rid = rep["waiting"].pop(0)
            rep["rows"][rep["free"].pop(0)] = [rid, service]
            admitted += 1
        for slot in rep["rows"]:
            rep["rows"][slot][1] -= 1
        for slot in sorted(rep["rows"]):
            rid, left = rep["rows"][slot]
            if left <= 0:
                results.append((rid, "ok"))
                del rep["rows"][slot]
                rep["free"].append(slot)

    def fleet_tick():
        nonlocal tick_no
        for rep in list(reps.values()):
            sched_tick(rep)
        for rrid in [k for k, r in reps.items()
                     if r["draining"] and not r["waiting"] and not r["rows"]]:
            del reps[rrid]
        delta = scale_script.get(tick_no, 0)
        live = {k: r for k, r in reps.items() if not r["draining"]}
        if delta > 0:
            add_replica()
        elif delta < 0 and len(live) > 1:
            victim = min(live, key=lambda k: (len(live[k]["waiting"]),
                                              len(live[k]["rows"]), -k))
            reps[victim]["draining"] = True
        tick_no += 1

    def submit(rid, dl, prio):
        nonlocal seq
        live = {k: r for k, r in reps.items() if not r["draining"]}

        def route_key(k):
            dls = [w[1] for w in live[k]["waiting"] if w[1] != _INF]
            slack = (min(dls) - tick_no) if dls else _INF
            return (len(live[k]["waiting"]), -slack, k)

        rep = live[min(live, key=route_key)]
        if max_queue is not None and len(rep["waiting"]) >= max_queue:
            results.append((rid, "rejected"))
            return
        rep["waiting"].append((prio, _INF if dl is None else tick_no + dl,
                               seq, rid))
        seq += 1

    for idle, burst in trace:
        for _ in range(idle):
            fleet_tick()
        for rid, dl, prio in burst:
            submit(rid, dl, prio)
    while any(r["waiting"] or r["rows"] for r in reps.values()):
        fleet_tick()
    return results


def assert_fleet_trace_ok(n_replicas, width, service, trace, *,
                          max_queue=None, scale_script=None):
    got, summary = run_fleet_trace(n_replicas, width, service, trace,
                                   max_queue=max_queue,
                                   scale_script=scale_script)
    want = reference_fleet_trace(n_replicas, width, service, trace,
                                 max_queue=max_queue,
                                 scale_script=scale_script)
    label = (f"replicas={n_replicas} width={width} service={service} "
             f"max_queue={max_queue} scale={scale_script} trace={trace!r}")
    assert got == want, f"fleet diverged\n got {got}\nwant {want}\n{label}"
    # conservation: every submitted rid surfaces exactly once
    submitted = [rid for _, burst in trace for rid, _, _ in burst]
    surfaced = sorted(rid for rid, _ in got)
    assert surfaced == sorted(submitted), f"lost/duplicated rids\n{label}"
    assert summary["requests_lost"] == 0
    # deterministic replay: an identical run yields the identical stream
    got2, summary2 = run_fleet_trace(n_replicas, width, service, trace,
                                     max_queue=max_queue,
                                     scale_script=scale_script)
    assert got2 == got and summary2 == summary, f"replay diverged\n{label}"


def _random_fleet_trace(rng):
    n_replicas = int(rng.integers(1, 4))
    width = int(rng.integers(1, 4))
    service = int(rng.integers(1, 4))
    max_queue = None if rng.integers(0, 2) == 0 else int(rng.integers(1, 7))
    trace, rid = [], 0
    for _ in range(int(rng.integers(1, 6))):
        idle = int(rng.integers(0, 4))
        burst = []
        for _ in range(int(rng.integers(0, 4 * width + 1))):
            dl = None if rng.integers(0, 2) == 0 else int(rng.integers(0, 7))
            burst.append((rid, dl, int(rng.integers(0, 3))))
            rid += 1
        trace.append((idle, burst))
    script = {int(rng.integers(0, 13)): int(rng.choice([-1, 1]))
              for _ in range(int(rng.integers(0, 4)))}
    return n_replicas, width, service, trace, max_queue, script


def test_fleet_random_traces_match_reference():
    """Seeded sweep of the same property the fleet hypothesis test explores
    (tests/test_properties.py): random arrival traces with deadlines,
    priorities, bounded queues and scripted scale events must match the
    pure-python fleet reference, conserve every request, and replay
    deterministically."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        n, w, s, trace, mq, script = _random_fleet_trace(rng)
        assert_fleet_trace_ok(n, w, s, trace, max_queue=mq,
                              scale_script=script)


# ---------------------------------------------------------------------------
# Router dispatch + elasticity units
# ---------------------------------------------------------------------------

def test_router_least_depth_with_slack_tiebreak():
    router = Router(lambda: ModelBackend(1, 5), replicas=2)
    router.submit(_req(0, dl=2))            # both empty → replica 0
    router.submit(_req(1, dl=9))            # depth tie broken by id → 1
    assert [r.sched.queued for r in router.replicas.values()] == [1, 1]
    # depths tied again: replica 1's queued deadline has MORE slack (9 vs
    # 2), so it absorbs the next request — deadline pressure is load the
    # depth number can't see
    router.submit(_req(2))
    assert router.replicas[1].sched.queued == 2
    router.drain()
    assert router.metrics.lost == 0


def test_scale_down_drains_then_retires_never_strands():
    """A scripted scale-down mid-burst marks a replica draining: it accepts
    no new work but completes everything it holds before retiring."""
    router = Router(lambda: ModelBackend(1, 3), replicas=2,
                    autoscaler=ScriptedScaler({0: -1}),
                    keep_results=True)
    for rid in range(6):
        router.submit(_req(rid))
    router.drain()
    assert sorted(r.rid for r in router.results) == list(range(6))
    assert all(r.finish_reason == "ok" for r in router.results)
    assert len(router.retired) == 1 and router.n_live == 1
    retired = next(iter(router.retired.values()))
    assert retired.queued == 0 and not retired.active
    assert router.metrics.lost == 0
    assert [e["action"] for e in router.metrics.scale_events] \
        == ["down", "retired"]


def test_scale_down_never_drains_last_live_replica():
    router = Router(lambda: ModelBackend(1, 1), replicas=1,
                    autoscaler=ScriptedScaler({0: -1, 1: -1}))
    router.submit(_req(0))
    router.drain()
    for _ in range(3):
        router.tick()
    assert router.n_live == 1 and not router.metrics.scale_events


def test_scale_up_takes_traffic_and_timeline_records_it():
    router = Router(lambda: ModelBackend(1, 2), replicas=1,
                    autoscaler=ScriptedScaler({1: +1}))
    for rid in range(8):                    # arrivals span the scale event
        router.submit(_req(rid))
        router.tick()
    router.drain()
    assert router.metrics.lost == 0
    summary = router.metrics.summary()
    assert summary["replicas_max"] == 2 and summary["replicas_min"] == 1
    # the spawned replica actually served part of the backlog
    per_replica = router.engine_summaries()
    assert len(per_replica) == 2
    assert all(s["requests_completed"] > 0 for s in per_replica.values())


# ---------------------------------------------------------------------------
# Autoscaler hysteresis
# ---------------------------------------------------------------------------

def _stub_replica(depths, occs, capacity=2):
    return types.SimpleNamespace(metrics=types.SimpleNamespace(
        queue_depth=list(depths), occupancy=list(occs), tick_s=[0.0] * 8,
        capacity=capacity))


def test_autoscaler_watermarks_and_cooldowns():
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=3, window=4,
                           queue_high=2.0, occ_low=0.5,
                           cooldown_up=5, cooldown_down=10)
    auto = Autoscaler(cfg)
    # young replica (short metric history): hold regardless of pressure
    assert auto.decide(0, [_stub_replica([99] * 2, [1.0] * 2)]) == 0
    # sustained queue pressure → up; cooldown blocks an immediate repeat
    hot = [_stub_replica([9] * 8, [1.0] * 8)]
    assert auto.decide(10, hot) == +1
    assert auto.decide(12, hot) == 0               # within cooldown_up
    assert auto.decide(15, hot) == +1              # cooldown elapsed
    # at max_replicas: hold even under pressure
    assert auto.decide(30, [_stub_replica([9] * 8, [1.0] * 8)] * 3) == 0
    # idle fleet scales down only after the (longer) down cooldown
    idle = [_stub_replica([0] * 8, [0.0] * 8)] * 2
    assert auto.decide(20, idle) == 0              # within cooldown_down
    assert auto.decide(25, idle) == -1
    # at min_replicas: never below the floor
    assert auto.decide(50, [_stub_replica([0] * 8, [0.0] * 8)]) == 0
    # busy-but-keeping-up (occupied, empty queue): hold, don't flap
    busy = [_stub_replica([0] * 8, [1.0] * 8)] * 2
    assert auto.decide(80, busy) == 0


# ---------------------------------------------------------------------------
# Completion deadlines + priorities (scheduler-level satellites)
# ---------------------------------------------------------------------------

def _drain(sched, guard=1000):
    while sched.queue or sched.active:
        sched.tick()
        guard -= 1
        assert guard > 0, "failed to drain"


def test_completion_deadline_drops_inflight_overrun():
    """In-flight work that overruns completion_deadline_ticks is dropped at
    harvest (finish_reason 'expired', counted as expired_inflight), its
    slot recycles, and the backend's late emissions are ignored."""
    sched = Scheduler(ModelBackend(1, service_ticks=5))
    sched.submit(_req(0, cd=3))
    sched.submit(_req(1))                   # proves the slot recycles
    _drain(sched)
    by = {r.rid: r for r in sched.results}
    assert by[0].finish_reason == "expired" and by[0].n_ticks == 3
    assert by[0].deadline_met is False
    assert by[1].finish_reason == "ok"
    assert sched.metrics.expired_inflight == 1
    assert sched.metrics.expired == 0
    assert sched.metrics.completed == 1


def test_completion_deadline_expires_hopeless_at_admission():
    """A waiter whose completion deadline already passed while queued never
    takes a slot: it expires at admission (n_ticks == 0, admission-expiry
    bucket — FleetMetrics tells the two causes apart structurally)."""
    sched = Scheduler(ModelBackend(1, service_ticks=10))
    sched.submit(_req(0))                   # blocks the only slot 10 ticks
    sched.submit(_req(1, cd=3))
    _drain(sched)
    by = {r.rid: r for r in sched.results}
    assert by[1].finish_reason == "expired" and by[1].n_ticks == 0
    assert sched.metrics.expired == 1
    assert sched.metrics.expired_inflight == 0


def test_completion_deadline_boundary_completes():
    """A request finishing exactly at its completion deadline completes —
    the drop only fires for work that can no longer finish in budget."""
    sched = Scheduler(ModelBackend(1, service_ticks=3))
    sched.submit(_req(0, cd=3))
    _drain(sched)
    assert sched.results[0].finish_reason == "ok"
    assert sched.results[0].n_ticks == 3
    assert sched.metrics.expired_inflight == 0


def test_priority_admission_order():
    """Lower priority number admits first; within a class, EDF with FIFO
    tie-break — a later-arriving priority-0 request overtakes queued
    priority-1 work."""
    backend = ModelBackend(1, service_ticks=1)
    sched = Scheduler(backend)
    sched.submit(_req(0, prio=1))
    sched.submit(_req(1, prio=1))
    sched.submit(_req(2, prio=0))
    sched.submit(_req(3, prio=0, dl=1))     # EDF inside class 0
    _drain(sched)
    assert [r.rid for r in sched.results] == [3, 2, 0, 1]


def test_priority_starvation_bounded_by_completion_deadline():
    """Strict priority can starve background work indefinitely under
    sustained foreground load — the starvation BOUND is the background
    class's completion deadline: a starved request is never SERVED past its
    budget (it expires without ever taking a slot, surfacing the overload
    instead of silently doing stale work), and once foreground pressure
    stops, surviving background work admits in FIFO order."""
    sched = Scheduler(ModelBackend(1, service_ticks=1))
    sched.submit(_req(100, prio=1, cd=6))   # background, bounded staleness
    sched.submit(_req(101, prio=1))         # background, unbounded
    rid = 0
    for _ in range(10):                     # sustained foreground pressure
        sched.submit(_req(rid, prio=0))
        rid += 1
        sched.tick()
    _drain(sched)
    by = {r.rid: r for r in sched.results}
    assert all(by[i].finish_reason == "ok" for i in range(10))
    # bounded-staleness background work expired without ever being served
    # past its budget (wait 10 ticks >> completion deadline 6, slot never
    # taken)...
    assert by[100].finish_reason == "expired"
    assert by[100].n_ticks == 0 and by[100].wait_ticks > 6
    # ...unbounded background work completed only after the pressure ended
    assert by[101].finish_reason == "ok"
    order = [r.rid for r in sched.results]
    assert order.index(101) > order.index(9)


# ---------------------------------------------------------------------------
# Metrics: NaN-free summaries + conservation identity
# ---------------------------------------------------------------------------

def _assert_nan_free(summary):
    for key, val in summary.items():
        if isinstance(val, float):
            assert math.isfinite(val), f"{key} = {val}"


def test_summary_nan_free_on_all_rejected_window():
    """Regression (referenced from EngineMetrics.summary): a tick window
    that completes NOTHING — every submission rejected by the bounded
    queue, plus empty drain ticks — must summarise to finite numbers, not
    NaN quantiles/ratios over empty windows."""
    sched = Scheduler(ModelBackend(1, service_ticks=1), max_queue=0)
    for rid in range(4):
        assert not sched.submit(_req(rid))
    summary = sched.metrics.summary()       # zero ticks recorded
    _assert_nan_free(summary)
    assert summary["requests_rejected"] == 4
    assert summary["requests_dropped"] == 4
    assert summary["latency_p50_ticks"] == 0.0
    sched.tick()                            # idle tick: still no completions
    _assert_nan_free(sched.metrics.summary())
    # the fleet roll-up honours the same contract
    fm = FleetMetrics(slo_ticks=4)
    _assert_nan_free(fm.summary())          # empty fleet
    for rid in range(3):
        fm.on_result(ServeResult(rid=rid, finish_reason="rejected"))
    summary = fm.summary()
    _assert_nan_free(summary)
    assert summary["slo_attainment"] == 0.0
    assert summary["requests_lost"] == 0


def test_fleet_metrics_classifies_drop_causes_structurally():
    fm = FleetMetrics(slo_ticks=4)
    fm.on_result(ServeResult(rid=0, finish_reason="ok", wait_ticks=1,
                             n_ticks=2))                      # within SLO
    fm.on_result(ServeResult(rid=1, finish_reason="ok", wait_ticks=9,
                             n_ticks=2))                      # SLO miss
    fm.on_result(ServeResult(rid=2, finish_reason="rejected"))
    fm.on_result(ServeResult(rid=3, finish_reason="expired"))  # n_ticks 0
    fm.on_result(ServeResult(rid=4, finish_reason="expired", n_ticks=2))
    assert fm.submitted == 5 and fm.completed == 2 and fm.lost == 0
    summary = fm.summary()
    assert summary["drops_by_cause"] == {"rejected": 1,
                                         "expired_admission": 1,
                                         "expired_inflight": 1}
    assert summary["slo_attainment"] == 1 / 5


# ---------------------------------------------------------------------------
# Traffic replay: deterministic under a fixed seed
# ---------------------------------------------------------------------------

def test_traffic_replay_deterministic_under_fixed_seed():
    from repro.launch.traffic import calibrate, replay_model
    cal = calibrate("benchmarks/results/BENCH_serve.json")
    runs = [replay_model("burst", 1, n_requests=3000, seed=7, cal=cal,
                         slo_ticks=12, autoscale=True, max_replicas=3)
            for _ in range(2)]
    for cell in runs:
        cell.pop("replay_seconds")          # the only wall-clock field
    assert runs[0] == runs[1]
    # burst trace length is sized for the EXPECTED spike mass; a given seed
    # realises fewer spikes, so only a loose floor is deterministic
    assert runs[0]["requests_submitted"] >= 3000 * 0.6
