"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

Every kernel runs in interpret mode (CPU) and is asserted allclose against
ref.py; the exact-int path is asserted bit-equal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.kernels.w1a8_conv import ops as conv_ops
from repro.kernels.w1a8_conv import ref as conv_ref
from repro.kernels.w1a8_matmul import kernel as mm_kernel
from repro.kernels.w1a8_matmul import ops as mm_ops
from repro.kernels.w1a8_matmul import ref as mm_ref


def _mm_case(m, k, n, seed):
    kw, ka, km = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(kw, (k, n))
    wp = packing.pack_signs(w, axis=0)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.int32).astype(jnp.uint8)
    mul = jax.random.uniform(km, (k,), jnp.float32, 0.01, 0.1)
    div = jax.random.uniform(km, (n,), jnp.float32, 0.5, 1.5)
    b = jax.random.normal(km, (n,), jnp.float32)
    return a, wp, mul, div, b


MM_SHAPES = [(1, 32, 8), (5, 70, 12), (16, 64, 128), (128, 512, 256),
             (300, 1152, 75), (2, 4608, 192), (257, 96, 130)]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_w1a8_matmul_matches_ref(m, k, n):
    a, wp, mul, div, b = _mm_case(m, k, n, seed=m * 31 + k + n)
    y_ref = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, b)
    y_ker = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=k, interpret=True)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=6e-3 * scale)


@pytest.mark.parametrize("m,k,n", [(16, 64, 128), (300, 1152, 75)])
def test_w1a8_matmul_requant_within_1lsb(m, k, n):
    a, wp, mul, div, b = _mm_case(m, k, n, seed=7)
    y = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, b)
    # realistic LSQ step: matched to the activation range (as training learns)
    step = float(jnp.max(jnp.abs(y))) / 255.0
    q_ref = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, b,
                                   out_step=jnp.float32(step))
    q_ker = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=k, out_step=step,
                               interpret=True)
    diff = np.abs(np.asarray(q_ker, np.int32) - np.asarray(q_ref, np.int32))
    assert (diff <= 1).mean() > 0.995, f"1-LSB agreement {(diff <= 1).mean()}"
    assert diff.mean() < 0.3


@pytest.mark.parametrize("m,k,n", [(8, 64, 128), (256, 512, 256), (32, 1024, 128)])
def test_w1a8_matmul_int_path_bit_exact(m, k, n):
    a, wp, *_ = _mm_case(m, k, n, seed=k)
    signs = packing.unpack_signs(wp, k, axis=0, dtype=jnp.int32)
    colsum = jnp.sum(signs, axis=0, dtype=jnp.int32).reshape(1, n)
    bm = max(8, min(m, 256))
    bk = min(k, 512)
    bn = min(n, 256)
    y = mm_kernel.w1a8_matmul_int_pallas(a, wp, colsum, bm=bm, bk=bk, bn=bn,
                                         interpret=True)
    y_ref = a.astype(jnp.int32) @ signs
    assert bool(jnp.all(y == y_ref)), "exact-int kernel must be bit-exact"


def test_w1a8_matmul_batched_leading_dims():
    a, wp, mul, div, b = _mm_case(12, 96, 40, seed=3)
    a3 = a.reshape(3, 4, 96)
    y = mm_ops.w1a8_matmul(a3, wp, mul, div, b, k=96, interpret=True)
    assert y.shape == (3, 4, 40)
    y2 = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=96, interpret=True)
    np.testing.assert_allclose(np.asarray(y).reshape(12, 40), np.asarray(y2),
                               rtol=0, atol=1e-5)


CONV_SHAPES = [(1, 4, 4, 8, 16), (2, 8, 8, 16, 32), (1, 10, 10, 64, 75),
               (1, 20, 20, 128, 128), (3, 7, 9, 24, 40)]


@pytest.mark.parametrize("b,h,w,cin,cout", CONV_SHAPES)
def test_w1a8_conv_matches_ref(b, h, w, cin, cout):
    kw, ka, km = jax.random.split(jax.random.PRNGKey(b * 100 + cin), 3)
    wgt = jax.random.normal(kw, (3, 3, cin, cout))
    wp = conv_ops.conv_pack_weights(wgt)
    a = jax.random.randint(ka, (b, h, w, cin), 0, 256, jnp.int32).astype(jnp.uint8)
    mul = jax.random.uniform(km, (cin,), jnp.float32, 0.01, 0.1)
    div = jax.random.uniform(km, (cout,), jnp.float32, 0.5, 1.5)
    bias = jax.random.normal(km, (cout,), jnp.float32)
    y_ref = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
    y_ker = conv_ops.w1a8_conv3x3(a, wp, mul, div, bias, cin=cin,
                                  interpret=True)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=6e-3 * scale)


def test_w1a8_conv_requant_uint8():
    b, h, w, cin, cout = 1, 6, 6, 16, 24
    kw, ka, km = jax.random.split(jax.random.PRNGKey(0), 3)
    wgt = jax.random.normal(kw, (3, 3, cin, cout))
    wp = conv_ops.conv_pack_weights(wgt)
    a = jax.random.randint(ka, (b, h, w, cin), 0, 256, jnp.int32).astype(jnp.uint8)
    mul = jnp.full((cin,), 0.05, jnp.float32)
    div = jnp.ones((cout,), jnp.float32)
    bias = jnp.zeros((cout,), jnp.float32)
    y = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
    step = float(jnp.max(jnp.abs(y))) / 255.0
    q_ref = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias,
                                      out_step=jnp.float32(step))
    q_ker = conv_ops.w1a8_conv3x3(a, wp, mul, div, bias, cin=cin,
                                  out_step=step, interpret=True)
    assert q_ker.dtype == jnp.uint8
    diff = np.abs(np.asarray(q_ker, np.int32) - np.asarray(q_ref, np.int32))
    assert (diff <= 1).mean() > 0.995


@pytest.mark.parametrize("make_case", ["matmul", "conv"])
def test_requant_epilogue_rounding_matches_ref_across_zero(make_case):
    """Regression: kernel and ref epilogues must agree **bit-exact** on
    pre-clip values that straddle zero (incl. exact ±half-integers, the
    rounding boundary). Both now call core.quant.round_half_away; note the
    uint8 clip rail at 0 makes the old trunc(x+0.5) form observationally
    identical below zero, so what this locks is the shared rounding helper
    plus exact positive-side agreement — any future epilogue drift (ties,
    offsets, clip order) breaks the equality.

    The arithmetic is made exact on purpose: mul ≡ 1 keeps the bf16 MXU
    operands integral, so the only freedom left is the epilogue.
    """
    if make_case == "matmul":
        m, k, n = 16, 64, 128
        a, wp, *_ = _mm_case(m, k, n, seed=11)
        mul = jnp.ones((k,), jnp.float32)
        div = jnp.ones((n,), jnp.float32)
        # half-integer biases centred so pre-clip y/step straddles zero
        bias = (jnp.arange(n, dtype=jnp.float32) - n / 2) * 7.0 + 0.5
        y = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, bias)
        step = float(jnp.max(jnp.abs(y))) / 64.0          # many values < 0
        q_ref = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, bias,
                                       out_step=jnp.float32(step))
        q_ker = mm_ops.w1a8_matmul(a, wp, mul, div, bias, k=k,
                                   out_step=step, interpret=True)
    else:
        b, h, w, cin, cout = 1, 6, 6, 16, 24
        kw, ka = jax.random.split(jax.random.PRNGKey(12), 2)
        wgt = jax.random.normal(kw, (3, 3, cin, cout))
        wp = conv_ops.conv_pack_weights(wgt)
        a = jax.random.randint(ka, (b, h, w, cin), 0, 256,
                               jnp.int32).astype(jnp.uint8)
        mul = jnp.ones((cin,), jnp.float32)
        div = jnp.ones((cout,), jnp.float32)
        bias = (jnp.arange(cout, dtype=jnp.float32) - cout / 2) * 9.0 + 0.5
        y = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
        step = float(jnp.max(jnp.abs(y))) / 64.0
        q_ref = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias,
                                          out_step=jnp.float32(step))
        q_ker = conv_ops.w1a8_conv3x3(a, wp, mul, div, bias, cin=cin,
                                      out_step=step, interpret=True)
    q_ref, q_ker = np.asarray(q_ref, np.int32), np.asarray(q_ker, np.int32)
    assert (q_ref == 0).any() and (q_ref > 0).any(), "inputs must straddle 0"
    assert np.array_equal(q_ker, q_ref), \
        f"epilogue rounding drifted from ref ({np.abs(q_ker - q_ref).max()} LSB)"


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("out_step", [None, "auto"])
def test_w1a8_matmul_popcount_bit_exact_vs_dot(m, k, n, out_step):
    """XNOR-popcount accumulation vs the unpack-dot path, bit for bit, on
    every existing matmul test shape. Canonical operands (mul ≡ 1 folded
    into div) keep the dot path's bf16 operands exactly-representable
    integers, so both paths compute the same integer Σ s·a and run the
    same f32 epilogue — any deviation is a popcount bug, not noise."""
    a, wp, _, div, b = _mm_case(m, k, n, seed=m + 2 * k + 3 * n)
    m0 = 0.013
    mul = jnp.full((k,), m0, jnp.float32)
    ones = jnp.ones((k,), jnp.float32)
    if out_step == "auto":
        y = mm_ref.w1a8_matmul_ref(a, wp, k, mul, div, b)
        out_step = float(jnp.max(jnp.abs(y))) / 255.0
    y_pc = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=k, out_step=out_step,
                              accum="popcount", interpret=True)
    y_dot = mm_ops.w1a8_matmul(a, wp, ones, div * m0, b, k=k,
                               out_step=out_step, accum="dot", interpret=True)
    assert np.array_equal(np.asarray(y_pc), np.asarray(y_dot))
    # vs the jnp oracle: identical math, but XLA may contract the epilogue's
    # mul+add into an FMA differently outside Pallas — allow 1 ulp / 1 LSB.
    y_ref = mm_ref.w1a8_matmul_ref(
        a, wp, k, ones, div * m0, b,
        None if out_step is None else jnp.float32(out_step))
    diff = np.abs(np.asarray(y_pc, np.float64) - np.asarray(y_ref, np.float64))
    if out_step is None:
        assert diff.max() <= 4e-6 * (np.abs(np.asarray(y_ref)).max() + 1)
    else:
        assert diff.max() <= 1


@pytest.mark.parametrize("b,h,w,cin,cout", CONV_SHAPES)
@pytest.mark.parametrize("out_step", [None, "auto"])
def test_w1a8_conv_popcount_bit_exact_vs_dot(b, h, w, cin, cout, out_step):
    """Conv analogue of the popcount bit-exactness sweep, incl. the K9p
    padding lanes (9·Cin not a multiple of 32 for most shapes)."""
    kw, ka, km = jax.random.split(jax.random.PRNGKey(b * 7 + cin), 3)
    wgt = jax.random.normal(kw, (3, 3, cin, cout))
    wp = conv_ops.conv_pack_weights(wgt)
    a = jax.random.randint(ka, (b, h, w, cin), 0, 256,
                           jnp.int32).astype(jnp.uint8)
    m0 = 0.05
    mul = jnp.full((cin,), m0, jnp.float32)
    ones = jnp.ones((cin,), jnp.float32)
    div = jax.random.uniform(km, (cout,), jnp.float32, 0.5, 1.5)
    bias = jax.random.normal(km, (cout,), jnp.float32)
    if out_step == "auto":
        y = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
        out_step = float(jnp.max(jnp.abs(y))) / 255.0
    y_pc = conv_ops.w1a8_conv3x3(a, wp, mul, div, bias, cin=cin,
                                 out_step=out_step, accum="popcount",
                                 interpret=True)
    y_dot = conv_ops.w1a8_conv3x3(a, wp, ones, div * m0, bias, cin=cin,
                                  out_step=out_step, accum="dot",
                                  interpret=True)
    assert np.array_equal(np.asarray(y_pc), np.asarray(y_dot))
    # 1-ulp FMA slack vs the jnp oracle (see matmul variant for rationale)
    y_ref = conv_ref.w1a8_conv3x3_ref(
        a, wp, cin, ones, div * m0, bias,
        None if out_step is None else jnp.float32(out_step))
    diff = np.abs(np.asarray(y_pc, np.float64) - np.asarray(y_ref, np.float64))
    if out_step is None:
        assert diff.max() <= 4e-6 * (np.abs(np.asarray(y_ref)).max() + 1)
    else:
        assert diff.max() <= 1


def test_popcount_recovers_exact_integer_accumulation():
    """Neutral epilogue (div ≡ 1, bias ≡ 0, mul ≡ 1): the popcount path's
    output IS the integer Σ_k s_k·a_k — the binary-domain contraction is
    exact, not an approximation (where the dot path's bf16 prologue rounds
    as soon as mul ≠ 1)."""
    m, k, n = 32, 96, 64
    a, wp, *_ = _mm_case(m, k, n, seed=99)
    ones_k = jnp.ones((k,), jnp.float32)
    ones_n = jnp.ones((n,), jnp.float32)
    zeros_n = jnp.zeros((n,), jnp.float32)
    signs = packing.unpack_signs(wp, k, axis=0, dtype=jnp.int32)
    want = np.asarray(a, np.int64) @ np.asarray(signs, np.int64)
    got = mm_ops.w1a8_matmul(a, wp, ones_k, ones_n, zeros_n, k=k,
                             accum="popcount", interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_packing_roundtrip_axes():
    for axis, shape in [(0, (70, 12)), (1, (12, 70)), (0, (32, 5)), (0, (33, 4))]:
        w = jax.random.normal(jax.random.PRNGKey(axis + shape[0]), shape)
        pk = packing.pack_signs(w, axis=axis)
        un = packing.unpack_signs(pk, shape[axis], axis=axis)
        expect = np.where(np.asarray(w) >= 0, 1, -1)
        assert np.array_equal(np.asarray(un), expect)


POOL_SHAPES = [(1, 4, 4, 8, 16), (2, 8, 8, 16, 32), (1, 10, 10, 64, 75),
               (3, 6, 10, 24, 40)]


@pytest.mark.parametrize("b,h,w,cin,cout", POOL_SHAPES)
def test_fused_pool_popcount_bit_exact(b, h, w, cin, cout):
    """Fused conv+pool popcount datapath, on every even-plane kernel test
    shape (incl. ragged Cout=75 and the K9p-padded Cin=24): bit-exact vs
    (a) the fused DOT datapath under canonical operands (mul ≡ m0 folded
    into div keeps the dot prologue's bf16 operands exact integers — both
    paths compute the same Σ s·a and run the same requant+2×2-max
    epilogue) and (b) the unfused popcount-conv→reduce_window route under
    the original operands."""
    from repro.kernels.config import KernelConfig
    kw, ka, km = jax.random.split(jax.random.PRNGKey(b * 13 + cin), 3)
    wgt = jax.random.normal(kw, (3, 3, cin, cout))
    wp = conv_ops.conv_pack_weights(wgt)
    a = jax.random.randint(ka, (b, h, w, cin), 0, 256,
                           jnp.int32).astype(jnp.uint8)
    m0 = 0.05
    mul = jnp.full((cin,), m0, jnp.float32)
    ones = jnp.ones((cin,), jnp.float32)
    div = jax.random.uniform(km, (cout,), jnp.float32, 0.5, 1.5)
    bias = jax.random.normal(km, (cout,), jnp.float32)
    y = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
    step = float(jnp.max(jnp.abs(y))) / 255.0
    base = KernelConfig(op="conv3x3_pool", accum="popcount", out_step=step,
                        interpret=True)
    y_pc = conv_ops.w1a8_conv3x3_pool(a, wp, mul, div, bias, cin=cin,
                                      config=base.replace(fused=True))
    y_dot = conv_ops.w1a8_conv3x3_pool(
        a, wp, ones, div * m0, bias, cin=cin,
        config=base.replace(fused=True, accum="dot"))
    y_unf = conv_ops.w1a8_conv3x3_pool(a, wp, mul, div, bias, cin=cin,
                                       config=base.replace(fused=False))
    assert y_pc.dtype == jnp.uint8
    assert y_pc.shape == (b, h // 2, w // 2, cout)
    assert np.array_equal(np.asarray(y_pc), np.asarray(y_dot))
    assert np.array_equal(np.asarray(y_pc), np.asarray(y_unf))


def test_fused_conv_pool_matches_unfused():
    """Paper §5.2 Post+MaxPool fusion: one kernel == conv→requant→pool."""
    from repro.kernels.w1a8_conv.fused_pool import w1a8_conv3x3_pool2
    b, h, w, cin, cout = 1, 8, 8, 16, 32
    kw, ka, km = jax.random.split(jax.random.PRNGKey(5), 3)
    wgt = jax.random.normal(kw, (3, 3, cin, cout))
    wp = conv_ops.conv_pack_weights(wgt)
    a = jax.random.randint(ka, (b, h, w, cin), 0, 256, jnp.int32).astype(jnp.uint8)
    mul = jax.random.uniform(km, (cin,), jnp.float32, 0.01, 0.1)
    div = jax.random.uniform(km, (cout,), jnp.float32, 0.5, 1.5)
    bias = jax.random.normal(km, (cout,), jnp.float32)
    y = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias)
    step = float(jnp.max(jnp.abs(y))) / 255.0
    q = conv_ref.w1a8_conv3x3_ref(a, wp, cin, mul, div, bias,
                                  out_step=jnp.float32(step))
    want = jax.lax.reduce_window(q, jnp.uint8(0), jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    got = w1a8_conv3x3_pool2(a, wp, mul, div, bias, cin=cin, out_step=step,
                             interpret=True)
    assert got.shape == (b, h // 2, w // 2, cout)
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert (diff <= 1).mean() > 0.995 and diff.max() <= 2
