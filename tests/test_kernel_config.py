"""KernelConfig resolution + autotune harness + deprecation shim.

Covers the PR's API-redesign acceptance criteria: autotune table
round-trip (sweep → persist → load → identical winner), deterministic
tie-breaking, nearest-shape fallback on a miss, bit-exactness of every
tuned candidate vs the reference path on the kernel test shapes, and the
legacy-kwarg DeprecationWarning shim on all three kernel entry points and
DetectionBackend.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import config as kc
from repro.kernels.config import KernelConfig
from repro.kernels.w1a8_conv import ops as conv_ops
from repro.kernels.w1a8_matmul import ops as mm_ops
from repro.launch import autotune


# ---------------------------------------------------------------------------
# KernelConfig object semantics
# ---------------------------------------------------------------------------

def test_config_hashable_and_source_excluded():
    a = KernelConfig(op="conv3x3", rows=2, source="table")
    b = KernelConfig(op="conv3x3", rows=2, source="heuristic")
    assert a == b and hash(a) == hash(b)
    assert hash(a) != hash(a.replace(rows=4))
    jax.jit(lambda x, *, config: x, static_argnames=("config",))(
        jnp.zeros(()), config=a)          # static jit arg works


def test_config_validation():
    with pytest.raises(ValueError):
        KernelConfig(op="conv9x9")
    with pytest.raises(ValueError):
        KernelConfig(accum="fma")
    with pytest.raises(ValueError):
        KernelConfig(bk=48)               # not a PACK multiple
    with pytest.raises(ValueError):
        KernelConfig(rows=0)


def test_heuristic_tiles_match_legacy_pick():
    cfg = KernelConfig()
    assert cfg.matmul_tiles(300, 1152, 75) == (256, 512, 128)
    assert cfg.matmul_tiles(5, 70, 12) == (8, 96, 128)
    assert KernelConfig(bm=32).matmul_tiles(300, 1152, 75)[0] == 32
    assert KernelConfig(rows=4).conv_rows(10) == 2   # divisor clipping
    assert KernelConfig(rows=16).conv_rows(20) == 10


# ---------------------------------------------------------------------------
# Resolution: exact → nearest → heuristic
# ---------------------------------------------------------------------------

def _mini_table(tmp_path, entries):
    p = tmp_path / "AUTOTUNE_kernels.json"
    p.write_text(json.dumps({"version": 1, "entries": entries}))
    kc.clear_table_cache()
    return p


def test_resolve_exact_nearest_heuristic(tmp_path):
    dev = kc.device_key()
    key = kc.shape_key("conv3x3", (8, 8, 8, 16), "dot", dev)
    cfg = KernelConfig(op="conv3x3", rows=4, out_step=1.0)
    table = {key: {"config": cfg.to_dict(), "t_us": 10.0}}
    p = _mini_table(tmp_path, table)
    t = kc.load_table(p)
    exact = kc.resolve("conv3x3", (8, 8, 8, 16), accum="dot", table=t)
    assert exact.rows == 4 and exact.source == "table"
    near = kc.resolve("conv3x3", (10, 10, 8, 16), accum="dot", table=t)
    assert near.rows == 4 and near.source == "nearest"
    miss = kc.resolve("matmul", (100, 128, 64), accum="dot", table=t)
    assert miss.source == "heuristic" and miss.bm is None


def test_resolve_nearest_is_deterministic_on_ties(tmp_path):
    dev = kc.device_key()
    # two entries equidistant from the query; the smaller key must win
    e = {kc.shape_key("conv3x3", (8, 8, 8, 16), "dot", dev):
         {"config": KernelConfig(op="conv3x3", rows=2).to_dict()},
         kc.shape_key("conv3x3", (32, 32, 8, 16), "dot", dev):
         {"config": KernelConfig(op="conv3x3", rows=8).to_dict()}}
    p = _mini_table(tmp_path, e)
    t = kc.load_table(p)
    got = kc.resolve("conv3x3", (16, 16, 8, 16), accum="dot", table=t)
    want_key = min(kc.shape_key("conv3x3", (8, 8, 8, 16), "dot", dev),
                   kc.shape_key("conv3x3", (32, 32, 8, 16), "dot", dev))
    assert got.rows == KernelConfig.from_dict(
        e[want_key]["config"]).rows


def test_resolve_tuned_picks_fastest_accum():
    dev = kc.device_key()
    dims = (8, 8, 8, 16)
    t = {kc.shape_key("conv3x3", dims, "dot", dev):
         {"config": KernelConfig(op="conv3x3").to_dict(), "t_us": 20.0},
         kc.shape_key("conv3x3", dims, "popcount", dev):
         {"config": KernelConfig(op="conv3x3", accum="popcount").to_dict(),
          "t_us": 10.0}}
    got = kc.resolve_tuned("conv3x3", dims, table=t)
    assert got.accum == "popcount"
    got = kc.resolve_tuned("conv3x3", dims, allow_popcount=False, table=t)
    assert got.accum == "dot"


def test_table_env_override_and_missing_file(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE",
                       str(tmp_path / "nope.json"))
    kc.clear_table_cache()
    assert kc.load_table() == {}
    cfg = kc.resolve("conv3x3", (8, 8, 8, 16), accum="dot")
    assert cfg.source == "heuristic"
    monkeypatch.delenv("REPRO_AUTOTUNE_TABLE")
    kc.clear_table_cache()


# ---------------------------------------------------------------------------
# Autotune harness: round-trip + tie-break
# ---------------------------------------------------------------------------

def test_select_winner_tie_breaks_on_canonical_key():
    a = KernelConfig(op="conv3x3", rows=4)
    b = KernelConfig(op="conv3x3", rows=2)
    # equal times: winner must be the canonically-smaller config,
    # independent of measurement order
    w1 = autotune.select_winner([(5.0, a), (5.0, b)])
    w2 = autotune.select_winner([(5.0, b), (5.0, a)])
    assert w1 == w2
    assert w1[1] == min((a, b), key=lambda c: json.dumps(
        c.to_dict(), sort_keys=True))


def test_sweep_persist_load_roundtrip(tmp_path):
    """sweep → persist → load → resolve returns the identical winner."""
    dev = kc.device_key()
    op, dims, accum = "conv3x3", (8, 8, 8, 16), "dot"
    entry = autotune.sweep_cell(op, dims, accum, iters=1)
    key = kc.shape_key(op, dims, accum, dev)
    p = tmp_path / "AUTOTUNE_kernels.json"
    p.write_text(json.dumps({"version": 1, "entries": {key: entry}}))
    kc.clear_table_cache()
    loaded = kc.resolve(op, dims, accum=accum, table=kc.load_table(p))
    assert loaded == KernelConfig.from_dict(entry["config"])
    assert loaded.source == "table"


def test_roofline_accounting():
    r = autotune.roofline("matmul", (100, 128, 64))
    assert r["flops"] == 2 * 100 * 128 * 64 + 3 * 100 * 64
    assert r["bound"] in ("compute", "memory")
    assert r["t_model_us_v5e"] > 0
    rp = autotune.roofline("conv3x3_pool", (40, 40, 64, 128))
    rc = autotune.roofline("conv3x3", (40, 40, 64, 128))
    assert rp["bytes"] < rc["bytes"]      # pooled output writes 1/4 the plane


# ---------------------------------------------------------------------------
# Bit-exactness of tuned configs vs the reference path (kernel test shapes)
# ---------------------------------------------------------------------------

MM_SHAPES = [(5, 70, 12), (16, 64, 128), (257, 96, 130)]
CONV_SHAPES = [(2, 8, 8, 16, 32), (1, 10, 10, 64, 75), (3, 6, 10, 24, 40)]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_matmul_candidates_bit_exact(m, k, n):
    """Every candidate config matches its accum mode's reference path
    bit-for-bit (blocking changes the launch grid, not the math); dot vs
    popcount differ only by the dot path's bf16 prologue noise, which the
    kernel tests bound separately under canonical operands."""
    ops = autotune._operands("matmul", (m, k, n))
    for accum in ("dot", "popcount"):
        ref = None
        for cfg in autotune.candidates("matmul", (m, k, n), accum):
            out = np.asarray(autotune._call("matmul", ops, cfg))
            if ref is None:
                ref = out
            assert np.array_equal(out, ref), (accum, cfg)


@pytest.mark.parametrize("b,h,w,cin,cout", CONV_SHAPES)
def test_conv_candidates_bit_exact(b, h, w, cin, cout):
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 256, (b, h, w, cin), np.uint8))
    wt = jnp.asarray(rng.standard_normal((3, 3, cin, cout)), jnp.float32)
    wp = conv_ops.conv_pack_weights(wt)
    mul = jnp.full((cin,), 0.07, jnp.float32)
    div = jnp.asarray(rng.uniform(0.5, 2.0, (cout,)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((cout,)), jnp.float32)
    for accum in ("dot", "popcount"):
        ref = None
        for cfg in autotune.candidates("conv3x3", (h, w, cin, cout), accum):
            out = np.asarray(conv_ops.w1a8_conv3x3(
                a, wp, mul, div, bias, cin=cin, config=cfg))
            if ref is None:
                ref = out
            assert np.array_equal(out, ref), (accum, cfg)


def test_pool_candidates_bit_exact():
    b, h, w, cin, cout = 2, 8, 8, 16, 32
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 256, (b, h, w, cin), np.uint8))
    wt = jnp.asarray(rng.standard_normal((3, 3, cin, cout)), jnp.float32)
    wp = conv_ops.conv_pack_weights(wt)
    mul = jnp.full((cin,), 0.07, jnp.float32)
    div = jnp.asarray(rng.uniform(0.5, 2.0, (cout,)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((cout,)), jnp.float32)
    for accum in ("dot", "popcount"):
        ref = None
        for cfg in autotune.candidates("conv3x3_pool", (h, w, cin, cout),
                                       accum):
            out = np.asarray(conv_ops.w1a8_conv3x3_pool(
                a, wp, mul, div, bias, cin=cin, config=cfg))
            if ref is None:
                ref = out
            assert np.array_equal(out, ref), (accum, cfg)


def test_pool_fused_popcount_accepted():
    """fused=True + accum="popcount" is a valid cell: the fused conv+pool
    kernel has a popcount datapath, so the config constructs cleanly,
    dispatches without rejection, and matches the unfused
    popcount-conv→reduce_window route bit-for-bit. (This used to raise a
    dot-path-only ValueError at dispatch — the config/dispatch split the
    KernelConfig redesign was meant to remove.)"""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.integers(0, 256, (1, 4, 4, 8), np.uint8))
    wp = conv_ops.conv_pack_weights(
        jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32))
    mul = jnp.full((8,), 0.05, jnp.float32)
    div = jnp.asarray(rng.uniform(0.5, 2.0, (16,)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    base = KernelConfig(op="conv3x3_pool", accum="popcount", out_step=1.0,
                        interpret=True)
    got = conv_ops.w1a8_conv3x3_pool(a, wp, mul, div, bias, cin=8,
                                     config=base.replace(fused=True))
    want = conv_ops.w1a8_conv3x3_pool(a, wp, mul, div, bias, cin=8,
                                      config=base.replace(fused=False))
    assert got.dtype == jnp.uint8
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Deprecation shim
# ---------------------------------------------------------------------------

def _mm_operands():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 256, (4, 32), np.uint8))
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    wp = mm_ops.w1a8_pack_weights(w)
    mul = jnp.full((32,), 0.05, jnp.float32)
    div = jnp.ones((8,), jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    return a, wp, mul, div, b


def test_legacy_kwargs_warn_once_and_match_config():
    a, wp, mul, div, b = _mm_operands()
    kc._deprecation_warned = False        # re-arm (warn-once pattern)
    with pytest.warns(DeprecationWarning, match="KernelConfig"):
        y_legacy = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=32,
                                      interpret=True, accum="dot")
    y_cfg = mm_ops.w1a8_matmul(a, wp, mul, div, b, k=32,
                               config=KernelConfig(interpret=True))
    assert np.array_equal(np.asarray(y_legacy), np.asarray(y_cfg))
    # second legacy call must NOT re-warn
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        mm_ops.w1a8_matmul(a, wp, mul, div, b, k=32, interpret=True)


def test_config_plus_legacy_kwargs_is_type_error():
    a, wp, mul, div, b = _mm_operands()
    with pytest.raises(TypeError, match="not both"):
        mm_ops.w1a8_matmul(a, wp, mul, div, b, k=32,
                           config=KernelConfig(), interpret=True)


def test_config_op_mismatch_raises():
    a, wp, mul, div, b = _mm_operands()
    with pytest.raises(ValueError, match="entry point"):
        mm_ops.w1a8_matmul(a, wp, mul, div, b, k=32,
                           config=KernelConfig(op="conv3x3"))


def test_detection_backend_legacy_kwargs_warn(tiny_detector):
    from repro.serve import backends
    art = tiny_detector
    backends._detect_kwargs_warned = False
    with pytest.warns(DeprecationWarning, match="profile"):
        be = backends.DetectionBackend(art, slots=1, fuse_pool=False)
    assert be.profile == "interpret"
    with pytest.raises(TypeError, match="not both"):
        backends.DetectionBackend(art, slots=1, profile="tuned",
                                  interpret=True)
    be2 = backends.DetectionBackend(art, slots=1)
    assert be2.profile == "tuned"


@pytest.fixture(scope="module")
def tiny_detector():
    from repro.models import yolo
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.integers(0, 256, (1, yolo.INPUT_SIZE,
                                             yolo.INPUT_SIZE, 3), np.uint8),
                       jnp.float32) / 256.0
    _, art = yolo.build_detector(jax.random.PRNGKey(0), imgs,
                                 profile="tuned")
    return art


# ---------------------------------------------------------------------------
# Profile plumbing: tuned == interpret bit-for-bit on the model forward
# ---------------------------------------------------------------------------

def test_yolo_profiles_bit_exact(tiny_detector):
    from repro.models import yolo
    art = tiny_detector
    rng = np.random.default_rng(1)
    img = jnp.asarray(rng.integers(0, 256, (1, yolo.INPUT_SIZE,
                                            yolo.INPUT_SIZE, 3), np.uint8),
                      jnp.float32) / 256.0
    base = np.asarray(yolo.yolo_forward_kernel(art, img,
                                               profile="interpret"))
    tuned = np.asarray(yolo.yolo_forward_kernel(art, img, profile="tuned"))
    assert np.array_equal(base, tuned)
    with pytest.raises(ValueError, match="profile"):
        yolo.yolo_forward_kernel(art, img, profile="fastest")
