"""End-to-end behaviour tests for the paper's system."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verify
from repro.data import pipeline as data
from repro.data.pipeline import yolo_target
from repro.models import detection, yolo
from repro.optim import adamw
from repro.train.yolo_qat import make_yolo_train_step, yolo_loss


def test_e2e_qat_deploy_verify_detect():
    """The paper's full pipeline: QAT train → parameter extraction →
    integer datapath → Table-6 alignment → decode+NMS."""
    ds = data.make_detection_dataset(2)
    img0, _, _ = data.detection_batch(ds, 0)
    params = yolo.calibrate_yolo(yolo.init_yolo_params(jax.random.PRNGKey(0)),
                                 img0)
    opt = adamw(1e-3)
    step = make_yolo_train_step(opt)
    state = opt[0](params)
    # training progress is judged like-for-like on one fixed held-out batch
    # (each train step draws a different random batch, so comparing
    # per-step losses across steps is batch noise, not learning signal)
    h_img, h_boxes, h_classes = data.detection_batch(ds, 999)
    h_target = yolo_target(h_boxes, h_classes)
    eval_loss = jax.jit(yolo_loss)
    loss_before = float(eval_loss(params, h_img, h_target))
    losses = []
    for i in range(8):
        img, boxes, classes = data.detection_batch(ds, i)
        params, state, m = step(params, state, img, boxes, classes)
        losses.append(float(m["loss"]))
    loss_after = float(eval_loss(params, h_img, h_target))
    assert np.isfinite(losses).all()
    assert np.isfinite([loss_before, loss_after]).all()
    assert loss_after < loss_before, (loss_before, loss_after, losses)

    art = yolo.deploy_yolo(params)
    img, boxes, classes = data.detection_batch(ds, 123)
    img_u8 = jnp.clip(jnp.round(img * 256.0), 0, 255).astype(jnp.uint8)
    out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                       np.float64)
    out_i = yolo.yolo_forward_int(art, np.asarray(img_u8)) / 2.0 ** 15
    rep = verify.compare("final_raw", out_i, out_f, lsb=0.02)
    # alignment must be in the paper's regime (Table 6); after only 8 QAT
    # steps corr ≈ 0.997 and keeps rising (0.99999 at 30 steps — see
    # examples/train_yolo_qat.py); MAE is already 10× below the paper's.
    assert rep.corr > 0.99, rep.row()
    assert rep.mean_abs < 0.01, rep.row()
    assert rep.within_1lsb == 1.0, rep.row()

    b, s, c = detection.postprocess(jnp.asarray(out_i, jnp.float32),
                                    score_thresh=0.05, max_out=8)
    assert b.shape == (2, 8, 4)
    assert bool(jnp.all(jnp.isfinite(b)))


def test_dryrun_matrix_complete_if_present():
    """When the dry-run artifacts exist, the 80-cell matrix must be clean."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "results", "dryrun.json")
    if not os.path.exists(path):
        return
    with open(path) as f:
        recs = json.load(f)
    by_mesh = {}
    for r in recs:
        by_mesh.setdefault(r["mesh"], []).append(r)
    for mesh, cells in by_mesh.items():
        assert len(cells) == 40, (mesh, len(cells))
        bad = [c for c in cells if c.get("status") not in ("ok", "skipped")]
        assert not bad, [(c["arch"], c["shape"], c.get("error", "")[:60])
                         for c in bad]
        skips = [c for c in cells if c.get("status") == "skipped"]
        assert len(skips) == 7, mesh          # long_500k × 7 full-attn archs
