"""Unit tests for the roofline tooling (HLO collective parsing, wire-byte
formulas, MODEL_FLOPS accounting) — the measurement substrate of §Roofline."""

from repro.launch import dryrun as dr

HLO = """
ENTRY %main {
  %ar = f32[128,4096]{1,0} all-reduce(f32[128,4096]{1,0} %x), replica_groups={}
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %y), dimensions={0}
  %a2a = bf16[16,8,64]{2,1,0} all-to-all(bf16[16,8,64]{2,1,0} %z)
  %cp = u8[32]{0} collective-permute(u8[32]{0} %w)
  %mm = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_sums_output_bytes():
    c = dr.parse_collectives(HLO)
    assert c["all-reduce"] == 128 * 4096 * 4
    assert c["all-gather"] == 16 * 512 * 2
    assert c["all-to-all"] == 16 * 8 * 64 * 2
    assert c["collective-permute"] == 32
    assert c["counts"]["all-reduce"] == 1
    assert c["reduce-scatter"] == 0


def test_wire_bytes_ring_formulas():
    coll = {"all-reduce": 100, "all-gather": 100, "reduce-scatter": 0,
            "all-to-all": 0, "collective-permute": 50}
    n = 16
    f = 15 / 16
    want = 2 * 100 * f + 100 * f + 50
    assert abs(dr.wire_bytes(coll, n) - want) < 1e-9


def test_model_flops_train_matches_6nd():
    """Dense arch: train FLOPs ≈ 6·N·tokens + attention term."""
    f = dr.model_flops("chatglm3-6b", "train_4k")
    n_params = 6.35e9                      # chatglm3-6b ≈ 6.35B (ours)
    tokens = 256 * 4096
    base = 6 * n_params * tokens
    assert f > base * 0.9                  # includes attention on top
    assert f < base * 1.6


def test_model_flops_moe_uses_active_params():
    """kimi: 1.04T total but ~32B active ⇒ train flops ≪ 6·1T·D."""
    f = dr.model_flops("kimi-k2-1t-a32b", "train_4k")
    tokens = 256 * 4096
    assert f < 6 * 100e9 * tokens          # well under a 100B-dense model
    assert f > 6 * 25e9 * tokens           # but at least the ~32B active


def test_model_flops_decode_linear_in_context():
    f32k = dr.model_flops("qwen2.5-14b", "decode_32k")
    # one token per row: decode flops ≈ 2·N·B + attention·context
    assert f32k > 2 * 14e9 * 128


def test_model_flops_swa_bounded():
    """mixtral long_500k decode: SWA caps the attention context at 4096."""
    f = dr.model_flops("mixtral-8x7b", "long_500k")
    # attention term must reflect the window, not the 524288 context
    attn_win = 1 * 4 * 32 * 128 * 4096 * 32       # B·4·H·hd·W·layers
    attn_full = 1 * 4 * 32 * 128 * 524288 * 32
    base = 2 * 12.9e9                              # active params × 1 token
    assert f < base + attn_full * 0.5              # far below full-context
    assert f > base * 0.9
    assert f > attn_win                            # window term is in there


def test_skip_reasons_match_design():
    from repro.configs.shapes import skip_reason
    assert skip_reason("gemma2-27b", "long_500k")
    assert not skip_reason("mamba2-1.3b", "long_500k")
    assert not skip_reason("mixtral-8x7b", "long_500k")
    assert not skip_reason("gemma2-27b", "train_4k")
