"""Single-process 1F1B/GPipe pipelined-training guards (fast CPU).

Runs on the 16 forced host devices set up by conftest.py -- no subprocess,
no second jax runtime. The heavyweight end-to-end checks live in
tests/dist_main.py; these cover the schedule algebra (bubble fraction,
stash depth), the sequential-oracle match, and the int8-wire gradient
envelope established in PR 1 (~1.4% rel err on unit-normal grads,
asserted < 3%).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.dist.collectives import dequantize_wire, quantize_wire
from repro.dist.pipeline import (
    _schedule_constants,
    bubble_fraction,
    bubble_fraction_1f1b,
    pipeline_train_reference,
    pipeline_train_step,
)

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 16,
    reason="needs the forced 16-device host platform (see conftest.py)",
)


def _stage_fn(w, x):
    return jnp.tanh(x @ w["w"] + w["b"])


def _loss_fn(top, y, aux):
    return jnp.mean((y @ top["head"] - aux["tgt"]) ** 2)


def _toy(n, num_micro, mb, d=16):
    key = jax.random.PRNGKey(0)
    ws = {
        "w": jax.random.normal(key, (n, d, d)) * 0.3,
        "b": jax.random.normal(jax.random.fold_in(key, 1), (n, d)) * 0.1,
    }
    head = jax.random.normal(jax.random.fold_in(key, 2), (d, d))
    x = jax.random.normal(jax.random.fold_in(key, 3), (num_micro, mb, d))
    tgt = jax.random.normal(jax.random.fold_in(key, 4), (num_micro, mb, d))
    return ws, {"head": head * 0.2}, x, {"tgt": tgt}


def _toy_sat(n, num_micro, mb, d=16):
    """Sign-dominated variant: weights scaled so every tanh saturates to
    ~±1 — the b1-wire contract (|out| ≈ const, information in the sign
    plane). Built on PRNGKey(0) like `_toy` but with wscale 3.0 / x×2."""
    key = jax.random.PRNGKey(0)
    ws = {
        "w": jax.random.normal(key, (n, d, d)) * 3.0,
        "b": jax.random.normal(jax.random.fold_in(key, 1), (n, d)) * 0.1,
    }
    head = jax.random.normal(jax.random.fold_in(key, 2), (d, d))
    x = jax.random.normal(jax.random.fold_in(key, 3), (num_micro, mb, d)) * 2.0
    tgt = jax.random.normal(jax.random.fold_in(key, 4), (num_micro, mb, d))
    return ws, {"head": head * 0.2}, x, {"tgt": tgt}


def _qdq(x, qtype):
    return dequantize_wire(quantize_wire(x, qtype), x.dtype)


def _b1_wire_reference(stage_fn, loss_fn, ws, x, aux, top):
    """Sequential oracle with the b1 wire noise at every stage boundary.

    Emulates exactly what `pipeline_train_step(act_wire="b1")` computes,
    minus the schedule: forward activations cross each boundary as
    quantize→dequantize b1 (sign·α), backward cotangents as int8, and
    each stage's VJP runs at the dequantized stashed input. The pipelined
    schedules must match THIS reference tightly — the wire noise is the
    documented envelope, the schedule algebra must add nothing."""
    tm = jax.tree_util.tree_map
    n = jax.tree_util.tree_leaves(ws)[0].shape[0]
    num_m = x.shape[0]
    gw = tm(jnp.zeros_like, ws)
    gtop = tm(jnp.zeros_like, top)
    dxs = jnp.zeros_like(x)
    loss_acc = 0.0
    for m in range(num_m):
        h, fns = x[m], []
        for s in range(n):
            out, f = jax.vjp(stage_fn, tm(lambda le: le[s], ws), h)
            fns.append(f)
            if s < n - 1:
                h = _qdq(out, "b1")
        aux_m = tm(lambda a: a[m], aux)
        loss_m, (dtop_m, ct) = jax.value_and_grad(
            lambda tp, yy: loss_fn(tp, yy, aux_m), argnums=(0, 1)
        )(top, out)
        loss_acc += loss_m
        gtop = tm(lambda a, g: a + g, gtop, dtop_m)
        for s in reversed(range(n)):
            dw_s, dx = fns[s](ct)
            gw = tm(lambda a, g, s=s: a.at[s].add(g), gw, dw_s)
            if s > 0:
                ct = _qdq(dx, "s8")
        dxs = dxs.at[m].set(dx)
    inv = 1.0 / num_m
    return (
        loss_acc * inv,
        tm(lambda g: g * inv, gw),
        tm(lambda g: g * inv, gtop),
        dxs * inv,
    )


def _rel(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    d = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in zip(got_l, want_l)))
    nrm = jnp.sqrt(sum(jnp.sum(b**2) for b in want_l))
    return float(d / nrm)


def test_bubble_fraction_drops_vs_gpipe():
    # same (n, M): the 1F1B span is M+2n-1 ticks vs GPipe's 2(M+n-1)
    for num_micro in (4, 8, 16):
        gp = bubble_fraction(4, num_micro)
        ob = bubble_fraction_1f1b(4, num_micro)
        assert ob < gp, (num_micro, ob, gp)
    assert bubble_fraction_1f1b(1, 8) == 0.0
    assert bubble_fraction_1f1b(4, 32) < bubble_fraction_1f1b(4, 8)


def test_1f1b_stash_depth_is_o_n_not_o_m():
    assert _schedule_constants(4, 64, "1f1b")["ring"] == 7
    assert _schedule_constants(4, 64, "gpipe")["ring"] == 64
    assert _schedule_constants(4, 4, "1f1b")["ring"] == 4
    with pytest.raises(ValueError):
        _schedule_constants(4, 4, "zb-h1")


@needs_devices
@pytest.mark.parametrize("schedule", ["1f1b", "gpipe"])
def test_pipeline_train_matches_oracle(schedule):
    n, num_micro = 4, 8
    ws, top, x, aux = _toy(n, num_micro, mb=2)
    loss_ref, gws_ref, gtop_ref, dx_ref = pipeline_train_reference(
        _stage_fn, _loss_fn, ws, x, aux=aux, top=top
    )
    mesh = jax.make_mesh((n,), ("stage",))
    step = pipeline_train_step(
        _stage_fn,
        _loss_fn,
        mesh=mesh,
        axis="stage",
        num_micro=num_micro,
        schedule=schedule,
    )
    with mesh:
        loss, gws, gtop, dx = step(ws, x, aux=aux, top=top)
    assert abs(float(loss) - float(loss_ref)) / abs(float(loss_ref)) < 1e-5
    assert _rel(gws, gws_ref) < 1e-5
    assert _rel(gtop, gtop_ref) < 1e-5
    assert _rel(dx, dx_ref) < 1e-5


@needs_devices
@pytest.mark.parametrize("schedule", ["1f1b", "gpipe"])
def test_act_wire_int8_envelope(schedule):
    """int8 stage-boundary wire (activations fwd + cotangents bwd, each hop
    quantize→permute→dequantize at ≤ max|x|/254 per element): the 1F1B/
    GPipe training oracle match degrades from 1e-5 to a bounded few-percent
    envelope — the ICI-bandwidth/precision trade, asserted both ways
    (close to the oracle, but alive: the wire is actually quantized)."""
    n, num_micro = 4, 8
    ws, top, x, aux = _toy(n, num_micro, mb=2)
    loss_ref, gws_ref, gtop_ref, dx_ref = pipeline_train_reference(
        _stage_fn, _loss_fn, ws, x, aux=aux, top=top
    )
    mesh = jax.make_mesh((n,), ("stage",))
    step = pipeline_train_step(
        _stage_fn,
        _loss_fn,
        mesh=mesh,
        axis="stage",
        num_micro=num_micro,
        schedule=schedule,
        act_wire="int8",
    )
    with mesh:
        loss, gws, gtop, dx = step(ws, x, aux=aux, top=top)
    assert abs(float(loss) - float(loss_ref)) / abs(float(loss_ref)) < 0.02
    assert _rel(gws, gws_ref) < 0.05
    assert _rel(gtop, gtop_ref) < 0.05
    assert _rel(dx, dx_ref) < 0.05
    assert _rel(gws, gws_ref) > 1e-7          # quantization actually on wire


@needs_devices
@pytest.mark.parametrize("schedule", ["1f1b", "gpipe"])
def test_act_wire_b1_envelope(schedule):
    """b1 stage-boundary wire (packed signs + α forward, int8 cotangents
    backward), asserted both directions twice over: (1) the pipelined
    schedules match the b1-wire sequential reference at oracle tightness —
    schedule algebra adds nothing on top of the wire noise; (2) vs the
    CLEAN fp32 oracle the loss sits inside the documented few-percent
    envelope on a sign-dominated (saturated-tanh) toy, yet measurably off
    it — the 1-bit wire is actually on. Gradients vs the clean oracle are
    deliberately NOT enveloped: saturated-tanh VJPs are exponentially
    sensitive to the sign·α forward perturbation (see DESIGN.md §16)."""
    n, num_micro = 4, 8
    ws, top, x, aux = _toy_sat(n, num_micro, mb=2)
    loss_c, gws_c, _, _ = pipeline_train_reference(
        _stage_fn, _loss_fn, ws, x, aux=aux, top=top
    )
    loss_ref, gws_ref, gtop_ref, dx_ref = _b1_wire_reference(
        _stage_fn, _loss_fn, ws, x, aux, top
    )
    mesh = jax.make_mesh((n,), ("stage",))
    step = pipeline_train_step(
        _stage_fn,
        _loss_fn,
        mesh=mesh,
        axis="stage",
        num_micro=num_micro,
        schedule=schedule,
        act_wire="b1",
    )
    with mesh:
        loss, gws, gtop, dx = step(ws, x, aux=aux, top=top)
    # (1) schedule correctness under the b1 wire: oracle-tight
    assert abs(float(loss) - float(loss_ref)) / abs(float(loss_ref)) < 1e-5
    assert _rel(gws, gws_ref) < 1e-4
    assert _rel(gtop, gtop_ref) < 1e-4
    assert _rel(dx, dx_ref) < 1e-4
    # (2) documented envelope vs the clean oracle — and alive
    assert abs(float(loss) - float(loss_c)) / abs(float(loss_c)) < 0.05
    assert abs(float(loss) - float(loss_c)) / abs(float(loss_c)) > 1e-7
    assert _rel(gws, gws_c) > 1e-7            # 1-bit wire actually on


def test_act_wire_validated():
    with pytest.raises(ValueError, match="act_wire"):
        pipeline_train_step(_stage_fn, _loss_fn,
                            mesh=jax.make_mesh((2,), ("stage",)),
                            axis="stage", num_micro=2, act_wire="fp16")


@needs_devices
@pytest.mark.parametrize("wire,tol", [("fp32", 1e-5), ("int8", 0.03)])
def test_dp_grad_wire_envelope(wire, tol):
    n, num_micro = 2, 4
    ws, top, x, aux = _toy(n, num_micro, mb=8)
    ref = pipeline_train_reference(_stage_fn, _loss_fn, ws, x, aux=aux, top=top)
    mesh = jax.make_mesh((n, 8), ("stage", "data"))
    step = pipeline_train_step(
        _stage_fn,
        _loss_fn,
        mesh=mesh,
        axis="stage",
        num_micro=num_micro,
        dp_axis="data",
        grad_wire=wire,
    )
    with mesh:
        loss, gws, gtop, _ = step(ws, x, aux=aux, top=top)
    assert abs(float(loss) - float(ref[0])) < 1e-5
    assert _rel(gws, ref[1]) < tol
    assert _rel(gtop, ref[2]) < tol
