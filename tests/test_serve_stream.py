"""serve v3 streaming tests: scheduler burst/deadline traces against a pure
python reference model, device-side done-mask decode equivalence, and
K-deep pipelined detection serving (depth=2) bit-exactness — including the
trained-regime NMS-set check that closes PR 3's σ(0)² tied-score gap.

`LifetimeBackend` / `run_trace` / `reference_trace` / `assert_trace_ok` are
also imported by the hypothesis property in tests/test_properties.py; keep
them dependency-free (no jax in the trace machinery).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import init_lm_params, lm_forward
from repro.serve import (DetectionBackend, LMBackend, SamplingParams,
                         Scheduler, ServeRequest)
from repro.serve.api import Emission


# ---------------------------------------------------------------------------
# Scheduler trace property: scheduler vs a pure-python reference model
# ---------------------------------------------------------------------------

class LifetimeBackend:
    """Mock backend: 'detect' rows emit one final payload after `life`
    steps; 'lm' rows emit one token per step (the scheduler's max_new =
    life check terminates them). Mixed lifetimes make completions release
    slots in non-admission order."""

    def __init__(self, capacity, admit_width=None):
        self.capacity = capacity
        if admit_width is not None:
            self.admit_width = admit_width
        self.meta = {}           # rid -> (kind, life)
        self.rows = {}           # slot -> [rid, kind, life_left]
        self.admit_pages = []    # one [rid, ...] page per batched admit call
        self._ems = {}

    def register(self, rid, kind, life):
        self.meta[rid] = (kind, life)

    def admit(self, assignments):
        self.admit_pages.append([req.rid for _, req in assignments])
        for slot, req in assignments:
            kind, life = self.meta[req.rid]
            self.rows[slot] = [req.rid, kind, life]

    def step(self):
        for slot, rec in self.rows.items():
            rec[2] -= 1
            if rec[1] == "lm":
                self._ems.setdefault(slot, []).append(
                    Emission(kind="token", payload=7))
            elif rec[2] <= 0:
                self._ems.setdefault(slot, []).append(
                    Emission(kind="detections", payload={"rid": rec[0]},
                             final=True))

    def harvest(self):
        out, self._ems = self._ems, {}
        return out

    def release(self, slot):
        self.rows.pop(slot, None)


def run_trace(capacity, admit_width, trace, max_queue=None):
    """Drive the real Scheduler through an arrival trace.

    ``trace`` = [(idle_ticks, burst), ...]; burst = [(rid, kind, life,
    deadline_ticks), ...] or 5-tuples with a trailing priority (lower
    admits first; absent = 0). Checks slot-conservation invariants after
    every tick; returns ([(rid, finish_reason), ...] in completion order,
    admit pages)."""
    backend = LifetimeBackend(capacity, admit_width)
    sched = Scheduler(backend, max_queue=max_queue)

    def check_slots():
        assert len(sched.free) + len(sched.active) == capacity, "slot leak"
        assert set(sched.free).isdisjoint(sched.active), "slot double-booked"
        assert len(set(sched.free)) == len(sched.free), "duplicate free slot"

    for idle, burst in trace:
        for _ in range(idle):
            sched.tick()
            check_slots()
        for rid, kind, life, dl, *rest in burst:
            backend.register(rid, kind, life)
            sched.submit(ServeRequest(rid=rid, deadline_ticks=dl,
                                      priority=(rest[0] if rest else 0),
                                      sampling=SamplingParams(max_new=life)))
    guard = 0
    while sched.queue or sched.active:
        sched.tick()
        check_slots()
        guard += 1
        assert guard < 10_000, "scheduler failed to drain"
    assert sched.queue == [], "wait queue not empty after drain"
    assert sorted(sched.free) == list(range(capacity)), "leaked slots"
    return [(r.rid, r.finish_reason) for r in sched.results], \
        backend.admit_pages


def reference_trace(capacity, admit_width, trace, max_queue=None):
    """Pure-python oracle with the documented semantics: admission pages
    pop (priority, deadline, arrival-seq) — strict priority classes, EDF
    with FIFO tie-break within a class — bounded queue rejects at submit,
    overdue waiters expire at tick start in deadline order regardless of
    priority, slots recycle FIFO, completions surface in slot order within
    a tick."""
    width = admit_width or capacity
    waiting = []                 # (prio, dl, seq, rid)
    free = list(range(capacity))
    rows = {}                    # slot -> [rid, kind, life_left]
    results, admit_pages = [], []
    seq = 0
    tick = 0

    def do_tick():
        nonlocal waiting, tick
        overdue = sorted((w for w in waiting if w[1] < tick),
                         key=lambda w: (w[1], w[2]))
        for _, _, _, rid in overdue:
            results.append((rid, "expired"))
        waiting = sorted(w for w in waiting if w[1] >= tick)
        page = []
        while waiting and free and len(page) < width:
            _, _, _, rid = waiting.pop(0)
            slot = free.pop(0)
            rows[slot] = [rid, *meta[rid]]
            page.append(rid)
        if page:
            admit_pages.append(page)
        for slot in sorted(rows):
            rows[slot][2] -= 1
        for slot in sorted(rows):
            rid, kind, life = rows[slot]
            if life <= 0:
                results.append((rid, "ok" if kind == "detect" else "length"))
                del rows[slot]
                free.append(slot)
        tick += 1

    meta = {}
    for idle, burst in trace:
        for _ in range(idle):
            do_tick()
        for rid, kind, life, dl, *rest in burst:
            meta[rid] = [kind, life]
            if max_queue is not None and len(waiting) >= max_queue:
                results.append((rid, "rejected"))
                continue
            waiting.append((rest[0] if rest else 0,
                            float("inf") if dl is None else tick + dl,
                            seq, rid))
            seq += 1
    while waiting or rows:
        do_tick()
    return results, admit_pages


def assert_trace_ok(capacity, admit_width, trace, max_queue=None):
    got, got_pages = run_trace(capacity, admit_width, trace, max_queue)
    want, want_pages = reference_trace(capacity, admit_width, trace,
                                       max_queue)
    label = (f"capacity={capacity} admit_width={admit_width} "
             f"max_queue={max_queue} trace={trace!r}")
    assert got_pages == want_pages, \
        f"admission order diverged\n got {got_pages}\nwant {want_pages}\n{label}"
    assert got == want, \
        f"results diverged\n got {got}\nwant {want}\n{label}"


def _random_trace(rng):
    capacity = int(rng.integers(1, 5))
    admit_width = (None if rng.integers(0, 2) == 0
                   else int(rng.integers(1, capacity + 1)))
    trace, rid = [], 0
    for _ in range(int(rng.integers(1, 5))):
        idle = int(rng.integers(0, 3))
        burst = []
        for _ in range(int(rng.integers(1, 4 * capacity + 1))):  # 1..4B
            kind = ["lm", "detect"][int(rng.integers(0, 2))]
            life = int(rng.integers(1, 4))
            dl = None if rng.integers(0, 2) == 0 else int(rng.integers(0, 7))
            prio = int(rng.integers(0, 3))
            burst.append((rid, kind, life, dl, prio))
            rid += 1
        trace.append((idle, burst))
    max_queue = (None if rng.integers(0, 2) == 0
                 else int(rng.integers(1, 3 * capacity + 1)))
    return capacity, admit_width, trace, max_queue


def test_scheduler_random_traces_match_reference():
    """Seeded sweep of the same property the hypothesis test explores
    (tests/test_properties.py): random bursts of 1–4B requests with mixed
    lm/detect lifetimes and deadlines must admit FIFO-within-deadline,
    never leak slots, and drain the wait queue."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        assert_trace_ok(*_random_trace(rng))


def test_scheduler_bounded_queue_rejects_overflow():
    trace = [(0, [(i, "detect", 1, None) for i in range(8)])]
    results, _ = run_trace(2, None, trace, max_queue=5)
    by = {}
    for rid, reason in results:
        by.setdefault(reason, []).append(rid)
    # capacity-2 pool: 5 queued, the 6th..8th submissions bounce
    assert by["rejected"] == [5, 6, 7]
    assert sorted(by["ok"]) == [0, 1, 2, 3, 4]


def test_scheduler_deadline_edf_and_expiry():
    """Deadlined requests overtake later-deadlined FIFO traffic; a waiter
    whose admission deadline passes expires with finish_reason
    'expired'."""
    trace = [(0, [(0, "detect", 2, None), (1, "detect", 2, None),
                  (2, "detect", 2, 20), (3, "detect", 2, 0),
                  (4, "detect", 2, 3)])]
    results, pages = run_trace(1, None, trace)
    assert pages[0] == [3]                 # earliest deadline first
    assert [r for r, _ in results][:3] == [3, 4, 2]
    # rid 1 (deadline 0) arrives while rid 0 holds the only slot → expires
    trace = [(0, [(0, "detect", 3, None)]), (1, [(1, "detect", 1, 0)])]
    results, _ = run_trace(1, None, trace)
    assert (1, "expired") in results and (0, "ok") in results


# ---------------------------------------------------------------------------
# Device-side done-mask decode ≡ host-side per-tick stop checks
# ---------------------------------------------------------------------------

def _greedy_oracle(cfg, params, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(n):
        logits = lm_forward(cfg, params, toks, mode="float")
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        out.append(int(nxt[0]))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return out


@pytest.fixture(scope="module")
def lm_setup():
    cfg = configs.get_reduced("granite-20b")
    params = init_lm_params(jax.random.PRNGKey(6), cfg)
    return cfg, params


def _serve_lm(cfg, params, reqs, *, done_mask, slots=2):
    sched = Scheduler(LMBackend(cfg, params, slots=slots, max_len=32,
                                done_mask=done_mask, seed=17))
    results = sched.run(reqs)
    return {r.rid: (r.tokens, r.finish_reason, r.n_ticks)
            for r in results}, sched.metrics.summary()


def test_done_mask_token_for_token_equivalence(lm_setup):
    """Fused device-side stop detection must emit token-for-token identical
    sequences to the host-side per-tick check across greedy + temperature +
    multi-stop-token requests (seeded), including a request whose stop
    token appears in position 1."""
    cfg, params = lm_setup
    oracle = _greedy_oracle(cfg, params, [1, 2, 3], 8)

    def reqs():
        return [
            # stop token IS the first sampled (prefill) token
            ServeRequest(rid=0, prompt=[1, 2, 3], sampling=SamplingParams(
                max_new=8, stop_tokens=(oracle[0],))),
            # multi-stop set, hit mid-stream
            ServeRequest(rid=1, prompt=[1, 2, 3], sampling=SamplingParams(
                max_new=8, stop_tokens=(10_000, oracle[3]))),
            ServeRequest(rid=2, prompt=[4, 1, 2, 5], sampling=SamplingParams(
                max_new=6, temperature=0.8)),
            ServeRequest(rid=3, prompt=[7, 2, 3], sampling=SamplingParams(
                max_new=5)),
            ServeRequest(rid=4, prompt=[9, 9, 1], sampling=SamplingParams(
                max_new=3, temperature=1.2, stop_tokens=(3,))),
        ]

    host, host_summary = _serve_lm(cfg, params, reqs(), done_mask=False)
    dev, dm_summary = _serve_lm(cfg, params, reqs(), done_mask=True)
    assert dev == host, f"\ndev  {dev}\nhost {host}"
    assert dev[0][0] == [oracle[0]] and dev[0][1] == "stop"   # position 1
    assert dev[1][0] == oracle[:4] and dev[1][1] == "stop"
    assert dev[3][1] == "length" and len(dev[3][0]) == 5
    # the whole point: one done-bitmask read per tick (B×bool, vs the host
    # path's B×int32 token row), tokens fetched in bulk only at completion
    assert dm_summary["host_syncs"] == dm_summary["ticks"]
    assert 0 < dm_summary["completion_syncs"] <= dm_summary["ticks"]
    assert dm_summary["host_sync_bytes_per_tick"] == 2      # 2 slots × bool
    assert host_summary["host_sync_bytes_per_tick"] == 8    # 2 slots × i32


def test_done_mask_respects_slot_recycling(lm_setup):
    """6 requests through a 2-slot pool: recycled slots must reset the
    device-side token buffer / done bits."""
    cfg, params = lm_setup
    prompts = [[1 + i, 2, 3] for i in range(6)]

    def reqs():
        return [ServeRequest(rid=i, prompt=p,
                             sampling=SamplingParams(max_new=3 + i % 2))
                for i, p in enumerate(prompts)]

    host, _ = _serve_lm(cfg, params, reqs(), done_mask=False)
    dev, _ = _serve_lm(cfg, params, reqs(), done_mask=True)
    assert dev == host


# ---------------------------------------------------------------------------
# Double-buffered detection serving (overlap) — 4×B burst, bit-exactness
# ---------------------------------------------------------------------------

N_IMGS = 8          # 4× the slot width below
WIDTH = 2


@pytest.fixture(scope="module")
def served_burst():
    """Trained-regime detector fixture: conv11 steered so the served head
    is score-separated (objectness +2 on anchor 0 / −6 elsewhere, class 3
    at +2 vs −4) with an 8× weight scale keeping real data dependence —
    every image yields exactly 100 well-separated anchor-0 detections, so
    NMS-set equivalence is testable on the actual served path (PR 3 could
    only state it on synthetic heads: untrained heads tie all scores at
    σ(0)² ≈ 0.25)."""
    from repro.models import yolo
    rng = np.random.default_rng(0)
    imgs_u8 = rng.integers(0, 256, (N_IMGS, 320, 320, 3), np.uint8)
    fimg = jnp.asarray(imgs_u8, jnp.float32) / 256.0
    params = yolo.init_yolo_params(jax.random.PRNGKey(42))
    params = yolo.calibrate_yolo(params, fimg[:1])
    bias = np.zeros(75, np.float32)
    for a in range(3):
        bias[a * 25 + 4] = 2.0 if a == 0 else -6.0
        for c in range(20):
            bias[a * 25 + 5 + c] = 2.0 if (a == 0 and c == 3) else -4.0
    params["conv11"] = dict(params["conv11"],
                            w=params["conv11"]["w"] * 8.0,
                            b=jnp.asarray(bias))
    art = yolo.deploy_yolo_kernel(params)

    runs = {}
    for depth in (1, 2):
        backend = DetectionBackend(art, slots=WIDTH, depth=depth,
                                   max_out=120)
        backend.warmup()
        sched = Scheduler(backend, max_queue=N_IMGS)
        results = sched.run([ServeRequest(rid=i, image=imgs_u8[i])
                             for i in range(N_IMGS)])      # one 4×B burst
        runs[depth] = ({r.rid: r for r in results},
                       sched.metrics.summary())
    return params, imgs_u8, runs


def test_overlap_serving_bit_exact_vs_single_shot(served_burst):
    """With double-buffering on, served detections for the burst must match
    single-shot DetectionBackend outputs bit-exactly — same fixed-width
    executable, same batch composition, one tick later."""
    _, _, runs = served_burst
    single, _ = runs[1]
    overlap, _ = runs[2]
    assert sorted(overlap) == sorted(single) == list(range(N_IMGS))
    for rid in range(N_IMGS):
        a, b = single[rid].detections, overlap[rid].detections
        for leaf in ("raw", "boxes", "scores", "classes"):
            assert np.array_equal(a[leaf], b[leaf]), (rid, leaf)
        assert overlap[rid].finish_reason == "ok"
        assert overlap[rid].n_ticks == single[rid].n_ticks + 1  # harvest t+1


def test_overlap_burst_drains_with_bounded_syncs(served_burst):
    """A 4×B burst admits through the bounded wait queue with zero drops,
    keeps the device batch at the backend's admit width, and costs at most
    one blocking host sync per tick."""
    _, _, runs = served_burst
    _, summary = runs[2]
    assert summary["requests_dropped"] == 0
    assert summary["requests_completed"] == N_IMGS
    assert summary["host_syncs_per_tick"] <= 1.0
    assert summary["queue_depth_max"] >= N_IMGS - 2 * WIDTH  # burst > pool
    assert summary["ticks"] == N_IMGS // WIDTH + 1           # +1 drain tick
    _, ss = runs[1]
    assert ss["ticks"] == N_IMGS // WIDTH


def test_overlap_served_nms_sets_match_float_reference(served_burst):
    """Served (packed Pallas, double-buffered) NMS sets ≡ float-reference
    NMS sets on the score-separated head — raw within core.verify
    tolerance, detection sets identical under class/IoU/score matching."""
    from repro.core import verify
    from repro.models import detection, yolo
    params, imgs_u8, runs = served_burst
    by_rid, _ = runs[2]
    fimg = jnp.asarray(imgs_u8, jnp.float32) / 256.0
    ref_raw = yolo.yolo_forward_float(params, fimg)
    got_raw = np.stack([by_rid[i].detections["raw"]
                        for i in range(N_IMGS)])
    rep = verify.compare("served_raw_trained", got_raw,
                         np.asarray(ref_raw, np.float64), lsb=0.02)
    assert rep.max_abs < 0.02 and rep.within_1lsb == 1.0, rep.row()
    rb, rs, rc = detection.postprocess(ref_raw, max_out=120)
    for i in range(N_IMGS):
        d = by_rid[i].detections
        got = detection.detections_to_list(d["boxes"], d["scores"],
                                           d["classes"])
        want = detection.detections_to_list(rb[i], rs[i], rc[i])
        assert len(got) == len(want) == 100          # score-separated regime
        assert {g["class_id"] for g in got} == {3}
        unmatched = list(want)
        for g in got:
            for j, e in enumerate(unmatched):
                iou = float(detection.iou_cxcywh(
                    jnp.asarray(g["box_cxcywh"]),
                    jnp.asarray(e["box_cxcywh"])))
                if (g["class_id"] == e["class_id"] and iou > 0.9
                        and abs(g["score"] - e["score"]) < 0.01):
                    unmatched.pop(j)
                    break
            else:
                raise AssertionError(f"img {i}: unmatched detection {g}")


def test_fleet_router_real_backend_bit_exact(served_burst):
    """The same burst through a 2-replica fleet (Router + backend.spawn(),
    replicas sharing the template's compiled executable) must complete the
    same request-id set with BIT-EXACT detection payloads as the
    single-scheduler overlap run — routing must never change what a request
    computes."""
    from repro.models import yolo
    from repro.serve.fleet import FleetMetrics, Router
    params, imgs_u8, runs = served_burst
    art = yolo.deploy_yolo_kernel(params)
    template = DetectionBackend(art, slots=WIDTH, depth=2, max_out=120)
    template.warmup()                  # one compile covers every spawn()
    router = Router(template.spawn, replicas=2,
                    metrics=FleetMetrics(), keep_results=True)
    results = router.run([ServeRequest(rid=i, image=imgs_u8[i])
                          for i in range(N_IMGS)])
    assert router.metrics.lost == 0 and router.metrics.dropped == 0
    single, _ = runs[2]
    by_rid = {r.rid: r for r in results}
    assert sorted(by_rid) == sorted(single) == list(range(N_IMGS))
    for rid in range(N_IMGS):
        a, b = single[rid].detections, by_rid[rid].detections
        for leaf in ("raw", "boxes", "scores", "classes"):
            assert np.array_equal(a[leaf], b[leaf]), (rid, leaf)
    # both replicas actually served work (burst >> one replica's admit page)
    per_replica = router.engine_summaries()
    assert len(per_replica) == 2
    assert all(s["requests_completed"] > 0 for s in per_replica.values())


def test_fuse_pool_serving_forward_bit_exact(served_burst):
    """yolo_forward_kernel(fuse_pool=True) — the fused conv+requant+MaxPool
    stage chain the streaming backend can serve with — must match the
    unfused kernel path bit-exactly (guards the ops.w1a8_conv3x3_pool
    wrapper and the dispatch branch in yolo.py, not just the inner
    kernel)."""
    from repro.models import yolo
    params, imgs_u8, _ = served_burst
    art = yolo.deploy_yolo_kernel(params)
    fimg = jnp.asarray(imgs_u8[:2], jnp.float32) / 256.0
    plain = yolo.yolo_forward_kernel(art, fimg, fuse_pool=False)
    fused = yolo.yolo_forward_kernel(art, fimg, fuse_pool=True)
    assert np.array_equal(np.asarray(plain), np.asarray(fused))


# ---------------------------------------------------------------------------
# Deprecation shim: warn exactly once per process
# ---------------------------------------------------------------------------

def test_serve_engine_warns_exactly_once(lm_setup, monkeypatch):
    from repro.serve import batching
    cfg, params = lm_setup
    monkeypatch.setattr(batching, "_deprecation_warned", False)
    with pytest.warns(DeprecationWarning):
        batching.ServeEngine(cfg, params, slots=1, max_len=16)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any further warning raises
        batching.ServeEngine(cfg, params, slots=1, max_len=16)
