"""K-deep dispatch window + bucketed multi-resolution admission (PR 9).

Four layers of coverage, cheapest first:

1. `DispatchWindow` property: for K ∈ {1,2,4,8} over drain + burst arrival
   traces, the real Scheduler driving a windowed mock backend must emit
   exactly the (tick, batch-rids) schedule predicted by a pure-python
   oracle that re-implements the two window rules (depth rule: after a
   push at most K−1 batches stay resident; drain rule: a no-push tick
   retires exactly one) — and harvest order must equal dispatch order.
2. Per-bucket admission: flooding one resolution bucket must not starve a
   sibling bucket — the starved bucket admits on its arrival tick through
   the same scheduler (the single-admit_width regression this PR fixes).
3. Real detection backend: a K-sweep over a single-bucket stream is
   bit-exact vs the K=1 single-shot run and completes in ascending rid
   order at every depth; a mixed two-bucket stream serves each image with
   its own bucket's grid and matches the single-resolution reference
   bit-exactly.
4. Compose: detect→LM hand-off on one tick loop conserves every request
   (lost == 0, no duplicates) and the prompt is exactly the detection
   template.

Plus the `overlap=` → `depth=` deprecation shim contract.
"""
import warnings

import numpy as np
import pytest

from repro.serve import DispatchWindow, Scheduler, ServeRequest
from repro.serve.api import Emission


# ---------------------------------------------------------------------------
# 1. DispatchWindow vs pure-python oracle
# ---------------------------------------------------------------------------

class WindowedMockBackend:
    """Jax-free backend exercising DispatchWindow through the real
    Scheduler: admitted rows stage, step() dispatches the staged batch into
    the window and harvests due batches, every row emits one final payload
    at its batch's harvest tick."""

    def __init__(self, slots, depth):
        self.capacity = depth * slots
        self.admit_width = slots
        self.depth = depth
        self._rows = {}
        self._staged = []
        self._window = DispatchWindow(depth)
        self._due = []

    def admit(self, assignments):
        for slot, req in assignments:
            self._rows[slot] = req.rid
            self._staged.append(slot)

    def step(self):
        pushed = False
        if self._staged:
            self._window.push(list(self._staged))
            self._staged = []
            pushed = True
        self._due = self._window.pop_due(pushed=pushed)

    def harvest(self):
        out = {}
        for batch in self._due:
            for slot in batch:
                out[slot] = [Emission(kind="detections",
                                      payload={"rid": self._rows[slot]},
                                      final=True)]
        self._due = []
        return out

    def release(self, slot):
        self._rows.pop(slot, None)


def window_oracle(trace, slots, depth):
    """Pure-python prediction of the emission schedule.

    ``trace`` maps tick → [rids arriving]. Returns [(tick, (rids...)), ...]
    in emission order. Re-implements: FIFO admission capped by admit width
    and free slots, one batch dispatched per tick, and the two window
    retirement rules. Slots release at the harvest tick."""
    arrivals = {t: list(rids) for t, rids in trace.items()}
    capacity = depth * slots
    pending, window, emissions = [], [], []
    active = t = 0
    total = sum(len(v) for v in arrivals.values())
    done = 0
    while done < total or pending or window or arrivals:
        pending.extend(arrivals.pop(t, []))
        take = min(slots, capacity - active, len(pending))
        batch, pending = pending[:take], pending[take:]
        active += take
        pushed = False
        if batch:
            window.append(batch)
            pushed = True
        due = []
        if not pushed and window:
            due.append(window.pop(0))
        while len(window) >= depth:
            due.append(window.pop(0))
        for b in due:
            emissions.append((t, tuple(b)))
            active -= len(b)
            done += len(b)
        t += 1
        assert t < 10_000, "oracle failed to drain"
    return emissions


TRACES = {
    # one big burst: the window must saturate to depth K then drain
    "burst": {0: list(range(12))},
    # drip feed slower than the service rate: drain rule fires every gap
    "drip": {t: [t] for t in range(0, 16, 3)},
    # burst, silence (full drain), second burst
    "drain+burst": {0: [0, 1, 2, 3, 4], 20: [5, 6, 7, 8, 9, 10]},
    # ragged arrivals that stage partial batches
    "ragged": {0: [0], 1: [1, 2, 3], 2: [4], 7: [5, 6], 8: [7]},
}


@pytest.mark.parametrize("depth", [1, 2, 4, 8])
@pytest.mark.parametrize("name", sorted(TRACES))
def test_window_schedule_matches_oracle(depth, name):
    trace = TRACES[name]
    backend = WindowedMockBackend(slots=2, depth=depth)
    sched = Scheduler(backend)
    got = []
    sink_tick = [0]
    sched._sink = lambda res: got.append((sink_tick[0], res.rid,
                                          res.detections["rid"]))
    arrivals = {t: list(rids) for t, rids in trace.items()}
    horizon = max(arrivals) + 1
    for t in range(10_000):
        sink_tick[0] = t
        for rid in arrivals.pop(t, []):
            assert sched.submit(ServeRequest(rid=rid))
        sched.tick()
        if t >= horizon and not (sched.queue or sched.active):
            break
    else:
        raise AssertionError("scheduler failed to drain")

    # every payload carries its own rid (no cross-slot mixups)
    assert all(rid == payload for _, rid, payload in got)
    # group per tick and compare with the oracle's schedule. Within one
    # tick the scheduler surfaces rows in slot-id order (an implementation
    # detail); dispatch-order harvesting at batch granularity is asserted
    # inside DispatchWindow itself, so membership-per-tick is the contract.
    per_tick = {}
    for t, rid, _ in got:
        per_tick.setdefault(t, []).append(rid)
    want = {}
    for t, batch in window_oracle(trace, slots=2, depth=depth):
        want.setdefault(t, []).extend(batch)
    assert ({t: sorted(v) for t, v in per_tick.items()}
            == {t: sorted(v) for t, v in want.items()}), (name, depth)


def test_window_rules_directly():
    """depth=1 retires every push immediately; drain ticks retire exactly
    one; depth<1 is rejected; harvest-order assertion is armed."""
    with pytest.raises(ValueError):
        DispatchWindow(0)
    w = DispatchWindow(1)
    w.push("a")
    assert w.pop_due(pushed=True) == ["a"]       # depth rule at K=1
    w3 = DispatchWindow(3)
    w3.push("a"), w3.push("b")
    assert w3.pop_due(pushed=True) == []         # 2 resident < K
    w3.push("c")
    assert w3.pop_due(pushed=True) == ["a"]      # at K: oldest retires
    assert w3.pop_due(pushed=False) == ["b"]     # drain rule: exactly one
    assert w3.pop_due(pushed=False) == ["c"]
    assert w3.pop_due(pushed=False) == []        # empty window drains empty


# ---------------------------------------------------------------------------
# 2. Per-bucket admission: a full sibling bucket must not starve the other
# ---------------------------------------------------------------------------

class BucketMockBackend:
    """Two-bucket jax-free backend: bucket = image_shape[0]. Rows live one
    tick. Tracks the admit page composition per tick."""

    def __init__(self, slots, buckets=(64, 96)):
        self.buckets = tuple(buckets)
        self.capacity = len(self.buckets) * slots
        self.admit_width = len(self.buckets) * slots
        self.bucket_admit_width = slots
        self._rows = {}
        self.admit_pages = []

    def bucket_of(self, req):
        return int(req.image_shape[0])

    def admit(self, assignments):
        self.admit_pages.append([(req.rid, self.bucket_of(req))
                                 for _, req in assignments])
        for slot, req in assignments:
            self._rows[slot] = req.rid

    def step(self):
        pass

    def harvest(self):
        out = {slot: [Emission(kind="detections", payload={"rid": rid},
                               final=True)]
               for slot, rid in self._rows.items()}
        return out

    def release(self, slot):
        self._rows.pop(slot, None)


def test_starved_bucket_admits_past_full_sibling():
    """Regression (satellite 3): the scheduler's admit loop assumed one
    global admit width. Flood bucket 64 beyond its per-bucket width, then
    submit ONE bucket-96 request: it must admit on the same tick, popping
    PAST the deferred bucket-64 overflow, and the overflow must re-queue
    un-lost."""
    backend = BucketMockBackend(slots=2)
    sched = Scheduler(backend)
    for rid in range(6):                      # 6 × bucket-64 ≫ width 2
        assert sched.submit(ServeRequest(rid=rid, image_shape=(64, 64, 3)))
    assert sched.submit(ServeRequest(rid=100, image_shape=(96, 96, 3)))
    sched.tick()
    first = backend.admit_pages[0]
    # bucket 64 capped at its width, bucket 96 admitted the SAME tick
    assert [rb for rb in first if rb[1] == 64] == [(0, 64), (1, 64)]
    assert (100, 96) in first
    # deferred bucket-64 requests re-queued in order, nothing lost
    rest = sched.run()
    all_res = sched.results
    assert sorted(r.rid for r in all_res) == [0, 1, 2, 3, 4, 5, 100]
    assert all(r.finish_reason == "ok" for r in all_res)
    admitted_64 = [rb[0] for page in backend.admit_pages
                   for rb in page if rb[1] == 64]
    assert admitted_64 == [0, 1, 2, 3, 4, 5]  # original order preserved
    del rest


def test_queued_in_bucket_signal():
    """The router's per-bucket depth signal counts only the queried
    bucket's waiting requests."""
    backend = BucketMockBackend(slots=1)
    sched = Scheduler(backend)
    for rid in range(4):
        sched.submit(ServeRequest(rid=rid, image_shape=(64, 64, 3)))
    sched.submit(ServeRequest(rid=9, image_shape=(96, 96, 3)))
    assert sched.queued_in_bucket(64) == 4
    assert sched.queued_in_bucket(96) == 1
    assert sched.queued == 5


# ---------------------------------------------------------------------------
# 3. Real detection backend: K-sweep bit-exactness + multi-resolution
# ---------------------------------------------------------------------------

N_IMGS = 6
SLOTS = 2


@pytest.fixture(scope="module")
def two_bucket_detector():
    import jax
    import jax.numpy as jnp
    from repro.models import yolo
    rng = np.random.default_rng(7)
    imgs = {b: rng.integers(0, 256, (N_IMGS, b, b, 3), np.uint8)
            for b in (64, 96)}
    _, art = yolo.build_detector(
        jax.random.PRNGKey(0), jnp.asarray(imgs[64][:1], jnp.float32) / 256.0,
        profile="interpret", buckets=(64, 96))
    from repro.serve import DetectionBackend
    template = DetectionBackend(art, slots=SLOTS, depth=2,
                                profile="interpret")
    template.warmup()
    return art, imgs, template


def _serve(backend, reqs):
    return Scheduler(backend).run(reqs)


def test_kdeep_sweep_bit_exact_and_ordered(two_bucket_detector):
    """Single-bucket stream at K ∈ {1,2,4,8}: completion order is dispatch
    order (ascending rid) at EVERY depth, and payloads are bit-exact vs
    the K=1 single-shot run — deeper pipelining changes timing only."""
    _, imgs, template = two_bucket_detector
    reqs = lambda: [ServeRequest(rid=i, image=imgs[64][i])
                    for i in range(N_IMGS)]
    base = {r.rid: r.detections["raw"]
            for r in _serve(template.spawn(depth=1), reqs())}
    for depth in (1, 2, 4, 8):
        res = _serve(template.spawn(depth=depth), reqs())
        assert [r.rid for r in res] == list(range(N_IMGS)), depth
        for r in res:
            assert np.array_equal(r.detections["raw"], base[r.rid]), \
                (depth, r.rid)


def test_mixed_stream_matches_single_resolution_reference(
        two_bucket_detector):
    """Two resolution buckets through ONE scheduler: every image comes back
    on its own bucket's grid, bit-exact vs a single-resolution run of the
    same images — and completion follows per-bucket batch dispatch order,
    stable across depths."""
    _, imgs, template = two_bucket_detector
    # rid → (bucket, index): evens are 64s, odds are 96s
    pick = lambda rid: (64, rid // 2) if rid % 2 == 0 else (96, rid // 2)
    mixed = lambda: [ServeRequest(rid=rid, image=imgs[pick(rid)[0]]
                                  [pick(rid)[1]]) for rid in range(N_IMGS)]
    res2 = _serve(template.spawn(depth=2), mixed())
    assert sorted(r.rid for r in res2) == list(range(N_IMGS))
    for r in res2:
        bucket, _ = pick(r.rid)
        assert r.detections["raw"].shape == (bucket // 32, bucket // 32, 75)
    # bit-exact vs each bucket's single-resolution depth=1 reference
    for bucket in (64, 96):
        rids = [rid for rid in range(N_IMGS) if pick(rid)[0] == bucket]
        ref = _serve(template.spawn(depth=1),
                     [ServeRequest(rid=rid, image=imgs[bucket][pick(rid)[1]])
                      for rid in rids])
        ref_by_rid = {r.rid: r.detections["raw"] for r in ref}
        for r in res2:
            if r.rid in ref_by_rid:
                assert np.array_equal(r.detections["raw"],
                                      ref_by_rid[r.rid]), r.rid
    # dispatch order is stable across K (same batches, same sequence)
    res4 = _serve(template.spawn(depth=4), mixed())
    assert [r.rid for r in res4] == [r.rid for r in res2]


def test_unknown_resolution_rejected(two_bucket_detector):
    _, _, template = two_bucket_detector
    backend = template.spawn()
    with pytest.raises(ValueError, match="bucket"):
        backend.bucket_of(ServeRequest(rid=0, image_shape=(128, 128, 3)))


# ---------------------------------------------------------------------------
# 4. Compose: detect→LM on one tick loop, zero lost
# ---------------------------------------------------------------------------

def test_compose_pipeline_conserves_requests(two_bucket_detector):
    import jax
    from repro import configs
    from repro.models.transformer import init_lm_params
    from repro.serve import (ComposePipeline, ComposeRequest,
                             LMBackend, SamplingParams, detections_to_prompt)
    _, imgs, template = two_bucket_detector
    cfg = configs.get_reduced("chatglm3-6b")
    lm = LMBackend(cfg, init_lm_params(jax.random.PRNGKey(1), cfg),
                   slots=SLOTS, max_len=32, seed=0)
    pipe = ComposePipeline(template.spawn(depth=2), lm,
                           vocab=cfg.vocab_size)
    results = pipe.run([ComposeRequest(rid=i, image=imgs[64][i],
                                       sampling=SamplingParams(max_new=4))
                        for i in range(4)])
    s = pipe.summary()
    assert s["lost"] == 0 and s["duplicated"] == 0
    assert s["handoffs"] == len(results) == 4
    assert all(h.kind == "compose" for h in pipe.handoffs)
    for r in results:
        assert r.finish_reason in ("length", "stop")
        assert len(r.tokens) >= 1
        assert r.prompt == detections_to_prompt(r.detections,
                                                vocab=cfg.vocab_size)
        assert all(1 <= t < cfg.vocab_size for t in r.prompt)


def test_detections_to_prompt_template():
    from repro.serve import detections_to_prompt
    # compact device-NMS wire
    compact = {"valid": 2, "classes": np.array([3, 7, 0]),
               "scores": np.array([0.9, 0.8, 0.0])}
    p = detections_to_prompt(compact, vocab=64)
    assert p[0] == 1 and len(p) == 4          # DESCRIBE, COUNT, 2 classes
    # raw wire: scores > 0 mark live rows
    raw = {"scores": np.array([0.5, 0.0, 0.25]),
           "classes": np.array([3, 9, 7])}
    assert detections_to_prompt(raw, vocab=64) == p  # same classes {3, 7}
    assert detections_to_prompt(None, vocab=64)[1] \
        != detections_to_prompt(compact, vocab=64)[1]  # count differs
    with pytest.raises(ValueError):
        detections_to_prompt(None, vocab=3)


# ---------------------------------------------------------------------------
# overlap= → depth= deprecation shim
# ---------------------------------------------------------------------------

def test_overlap_shim_maps_and_warns_once(two_bucket_detector):
    import repro.serve.backends as backends
    from repro.serve import DetectionBackend
    art, _, _ = two_bucket_detector
    backends._detect_overlap_warned = False       # re-arm warn-once
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        b2 = DetectionBackend(art, slots=1, overlap=True,
                              profile="interpret")
        b1 = DetectionBackend(art, slots=1, overlap=False,
                              profile="interpret")
    assert b2.depth == 2 and b1.depth == 1
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1                         # warn ONCE per process
    assert "depth" in str(deps[0].message)


def test_overlap_and_depth_together_rejected(two_bucket_detector):
    from repro.serve import DetectionBackend
    art, _, _ = two_bucket_detector
    with pytest.raises(TypeError, match="not both"):
        DetectionBackend(art, slots=1, overlap=True, depth=4,
                         profile="interpret")
