"""Import jax before any test module: repro.launch.{dryrun,costs} only force
the 512-device XLA flag when jax is not yet imported (fresh script runs), so
touching jax here pins the test session to the real 1-device CPU backend."""
import jax  # noqa: F401
