"""Session-wide jax setup, imported before any test module.

Two jobs:

* Force the 16-device host platform pool (the same ``XLA_FLAGS`` the CI
  environment exports) *before* jax initialises, so single-process tests
  can build real multi-device meshes (tests/test_pipeline_unit.py) without
  a subprocess. An externally-provided device-count flag wins.
* Import jax eagerly: ``repro.launch.{dryrun,costs}`` only force their
  512-device pool when jax is not yet imported (fresh script runs), so
  touching jax here pins the test session's device count.
"""

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16 " + _flags

import jax  # noqa: E402, F401


class FakeProdMesh:
    """Production-sized (16, 16) mesh stand-in for sharding-rule tests --
    shapes only, no devices (param_spec never touches device state)."""

    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
