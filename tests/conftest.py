"""Import jax before any test module: repro.launch.{dryrun,costs} only force
the 512-device XLA flag when jax is not yet imported (fresh script runs), so
touching jax here pins the test session to the real 1-device CPU backend."""
import jax  # noqa: F401


class FakeProdMesh:
    """Production-sized (16, 16) mesh stand-in for sharding-rule tests —
    shapes only, no devices (param_spec never touches device state)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
