"""Training-stack tests: optimizers, grad-accum equivalence, checkpoint
restart (incl. elastic), loop preemption, data determinism, YOLO QAT step."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro import configs
from repro.data import pipeline as data
from repro.models import yolo
from repro.models.transformer import init_lm_params
from repro.optim import adafactor, adamw, apply_updates, sgdm
from repro.optim.schedules import cosine_schedule
from repro.train.loop import run_train
from repro.train.step import make_train_step
from repro.train.yolo_qat import make_yolo_train_step

tmap = jax.tree_util.tree_map


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_minimize_quadratic(opt_name):
    opt = {"adamw": adamw(0.1),
           "adafactor": adafactor(lambda s: 0.5 / jnp.sqrt(s.astype(jnp.float32))),
           "sgdm": sgdm(0.05)}[opt_name]
    init, update = opt
    params = _quad_params()
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05, f"{opt_name}: {float(loss(params))}"


def test_cosine_schedule_shape():
    sched = cosine_schedule(1e-3, 10, 100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1e-3) < 1e-9
    assert float(sched(100)) < 2e-4


def test_grad_accum_matches_full_batch():
    cfg = configs.get_reduced("qwen2.5-14b")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    ds = data.make_lm_dataset(cfg.vocab_size, 8, 8)
    toks, labels = data.lm_batch(ds, 0)
    batch = {"tokens": toks, "labels": labels}
    # sgdm: update ∝ grads, so accumulation equivalence is exact-ish
    # (adam would amplify 1e-8 summation-order noise to ±lr at sqrt(v)≈0)
    opt = sgdm(1e-2)
    s1 = make_train_step(cfg, opt, microbatches=1, remat=False)
    s4 = make_train_step(cfg, opt, microbatches=4, remat=False)
    p1, _, m1 = s1(params, opt[0](params), batch)
    p4, _, m4 = s4(params, opt[0](params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree_util.tree_leaves(p1),
                               jax.tree_util.tree_leaves(p4)))
    assert diff < 5e-5, f"accum mismatch {diff}"


def test_remat_matches_no_remat():
    cfg = configs.get_reduced("chatglm3-6b")
    params = init_lm_params(jax.random.PRNGKey(1), cfg)
    ds = data.make_lm_dataset(cfg.vocab_size, 8, 4)
    toks, labels = data.lm_batch(ds, 3)
    batch = {"tokens": toks, "labels": labels}
    opt = adamw(1e-3)
    pa, _, ma = make_train_step(cfg, opt, remat=False)(params, opt[0](params),
                                                       batch)
    pb, _, mb = make_train_step(cfg, opt, remat=True)(params, opt[0](params),
                                                      batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 1e-5


def test_loss_decreases_lm():
    cfg = configs.get_reduced("chatglm3-6b")
    params = init_lm_params(jax.random.PRNGKey(2), cfg)
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    state = opt[0](params)
    ds = data.make_lm_dataset(cfg.vocab_size, 16, 8)
    losses = []
    for i in range(40):
        toks, labels = data.lm_batch(ds, i)
        params, state, m = step(params, state,
                                {"tokens": toks, "labels": labels})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.25, losses[::8]


def test_data_pipeline_deterministic_and_sharded():
    ds = data.make_lm_dataset(1000, 32, 16)
    a1, _ = data.lm_batch(ds, 5)
    a2, _ = data.lm_batch(ds, 5)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    b, _ = data.lm_batch(ds, 6)
    assert not np.array_equal(np.asarray(a1), np.asarray(b))
    s0, _ = data.lm_batch(ds, 5, shard=0, num_shards=2)
    s1, _ = data.lm_batch(ds, 5, shard=1, num_shards=2)
    assert s0.shape == (8, 32)
    assert not np.array_equal(np.asarray(s0), np.asarray(s1))


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 3, tree, metadata={"x": 1})
    ckpt_lib.save_checkpoint(d, 7, tmap(lambda x: x * 2, tree))
    assert ckpt_lib.latest_step(d) == 7
    restored, meta = ckpt_lib.restore_checkpoint(d, 3, tree)
    assert meta == {"x": 1}
    for x, y in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_commit(tmp_path):
    tree = {"w": jnp.zeros((128, 128))}
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 1, tree, async_=True)
    ckpt_lib.wait_for_async()
    assert ckpt_lib.latest_step(d) == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Save unsharded, restore onto a 4-device mesh — elastic rescale."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 1, tree)
    devs = jax.devices()
    if len(devs) < 2:
        restored, _ = ckpt_lib.restore_checkpoint(d, 1, tree)
        assert np.array_equal(np.asarray(restored["w"]),
                              np.asarray(tree["w"]))
        return
    # largest power-of-two mesh that still divides the (8, 8) leaf
    n = next(d for d in (8, 4, 2) if len(devs) >= d)
    mesh = jax.make_mesh((n,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt_lib.restore_checkpoint(d, 1, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_run_train_with_restart(tmp_path):
    cfg = configs.get_reduced("granite-20b")
    opt = adamw(1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    ds = data.make_lm_dataset(cfg.vocab_size, 8, 4)

    def batch_fn(i):
        t, l = data.lm_batch(ds, i)
        return {"tokens": t, "labels": l}

    params = init_lm_params(jax.random.PRNGKey(3), cfg)
    state = opt[0](params)
    d = str(tmp_path)
    p1, s1, n1 = run_train(train_step=step_fn, params=params,
                           opt_state=state, batch_fn=batch_fn, steps=4,
                           ckpt_dir=d, ckpt_every=2, async_ckpt=False,
                           print_fn=lambda *_: None)
    assert ckpt_lib.latest_step(d) == 4
    # restart from checkpoint and continue
    template = {"params": params, "opt_state": state}
    restored, _ = ckpt_lib.restore_checkpoint(d, 4, template)
    p2, s2, n2 = run_train(train_step=step_fn, params=restored["params"],
                           opt_state=restored["opt_state"],
                           batch_fn=batch_fn, steps=6, start_step=4,
                           ckpt_dir=d, ckpt_every=2, async_ckpt=False,
                           print_fn=lambda *_: None)
    assert n2 == 6 and ckpt_lib.latest_step(d) == 6


def test_run_train_preemption(tmp_path):
    cfg = configs.get_reduced("granite-20b")
    opt = adamw(1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    ds = data.make_lm_dataset(cfg.vocab_size, 8, 4)
    params = init_lm_params(jax.random.PRNGKey(3), cfg)
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    open(os.path.join(d, "PREEMPT"), "w").close()

    def batch_fn(i):
        t, l = data.lm_batch(ds, i)
        return {"tokens": t, "labels": l}

    _, _, n = run_train(train_step=step_fn, params=params,
                        opt_state=opt[0](params), batch_fn=batch_fn,
                        steps=100, ckpt_dir=d, ckpt_every=50,
                        async_ckpt=False, print_fn=lambda *_: None)
    assert n == 1                      # preempted at the first boundary
    assert ckpt_lib.latest_step(d) == 1


def test_yolo_qat_loss_decreases():
    params = yolo.init_yolo_params(jax.random.PRNGKey(0))
    ds = data.make_detection_dataset(2)
    img, boxes, classes = data.detection_batch(ds, 0)
    params = yolo.calibrate_yolo(params, img)
    opt = adamw(2e-3)
    step = make_yolo_train_step(opt)
    state = opt[0](params)
    losses = []
    for i in range(6):
        img, boxes, classes = data.detection_batch(ds, i % 2)
        params, state, m = step(params, state, img, boxes, classes)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # batches alternate (i % 2): compare same-batch losses across epochs
    assert losses[4] < losses[0], losses
    assert losses[5] < losses[1], losses
