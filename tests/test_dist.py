"""Distribution-layer tests. The multi-device checks need their own process
(XLA device count is fixed at first jax init), so they run via subprocess."""
import os
import subprocess
import sys

import jax


def test_multi_device_suite():
    """EP MoE, TP-in-expert, GPipe, int8 all-reduce, sharded train, SP attn,
    1F1B/GPipe pipelined training vs jax.grad oracle, pipelined LM step."""
    script = os.path.join(os.path.dirname(__file__), "dist_main.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    assert "ALL DIST CHECKS PASSED" in res.stdout
    assert "1F1B/GPipe pipelined training" in res.stdout
    assert "pipelined LM train step OK" in res.stdout


def test_sharding_rules_cover_all_archs():
    """Every param leaf of every full config gets a legal PartitionSpec."""
    from repro import configs
    from repro.dist import sharding as shard_rules
    from repro.models.transformer import init_lm_params

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in configs.ARCH_NAMES:
        cfg = configs.get_config(name)
        sds = jax.eval_shape(
            lambda c=cfg: init_lm_params(jax.random.PRNGKey(0), c))
        sh = shard_rules.tree_shardings(sds, cfg, mesh)
        n = len(jax.tree_util.tree_leaves(sh))
        assert n == len(jax.tree_util.tree_leaves(sds))


def test_sharding_rules_shard_the_big_tensors():
    """On a (4,4) devices=1 stand-in mesh the spec strings must place the
    model axis on FFN/attention projections (not replicate everything)."""
    from conftest import FakeProdMesh as FakeMesh
    from repro import configs
    from repro.dist.sharding import param_spec

    cfg = configs.get_config("qwen2.5-14b")

    spec = param_spec("['slots'][0]['attn']['wq']['w']",
                      (5120, 5120), cfg, FakeMesh())
    assert "model" in str(spec)
    spec = param_spec("['slots'][0]['mlp']['down']['w']",
                      (13824, 5120), cfg, FakeMesh())
    assert "model" in str(spec)
    cfg_moe = configs.get_config("kimi-k2-1t-a32b")
    spec = param_spec("['slots'][0]['moe']['up']",
                      (384, 7168, 2048), cfg_moe, FakeMesh())
    assert "data" in str(spec) and "model" in str(spec)
