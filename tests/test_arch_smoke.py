"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (no NaNs).

Also checks that the FULL configs' parameter counts land near the published
sizes (structure-level fidelity of the configs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, applicable_shapes
from repro.models.transformer import init_lm_params, lm_forward

BATCH, SEQ = 2, 16


def _inputs(cfg, key):
    kw = {}
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size, jnp.int32)
    if cfg.family == "encdec":
        kw["encoder_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (BATCH, SEQ, cfg.d_model)) * 0.1
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (BATCH, cfg.prefix_len, cfg.d_model)) * 0.1
    return toks, kw


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_forward_smoke(name):
    cfg = configs.get_reduced(name)
    key = jax.random.PRNGKey(hash(name) % 2 ** 31)
    params = init_lm_params(key, cfg)
    toks, kw = _inputs(cfg, key)
    logits = lm_forward(cfg, params, toks, mode="w1a8_eval", **kw)
    extra = cfg.prefix_len if cfg.frontend == "vision" else 0
    assert logits.shape == (BATCH, SEQ + extra, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_train_step_smoke(name):
    """One SGD step through the QAT (w1a8_train) path; loss finite & grads flow."""
    cfg = configs.get_reduced(name)
    key = jax.random.PRNGKey(hash(name) % 2 ** 31 + 1)
    params = init_lm_params(key, cfg)
    toks, kw = _inputs(cfg, key)
    labels = jnp.roll(toks, -1, axis=1)

    def loss_fn(p):
        logits = lm_forward(cfg, p, toks, mode="w1a8_train", **kw)
        logits = logits[:, -SEQ:, :]                      # drop any prefix
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    new = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = loss_fn(new)
    assert np.isfinite(float(loss2))
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0, f"{name}: zero gradients"


# Published sizes (total params, rounded) for structural validation.
EXPECTED_PARAMS_B = {
    "kimi-k2-1t-a32b": (1000, 0.10),
    "mixtral-8x7b": (46.7, 0.10),
    "mamba2-1.3b": (1.3, 0.25),
    "gemma2-27b": (27.2, 0.15),
    "chatglm3-6b": (6.2, 0.20),
    "qwen2.5-14b": (14.7, 0.15),
    "granite-20b": (20.1, 0.20),
    "jamba-1.5-large-398b": (398, 0.12),
    "internvl2-76b": (70.0, 0.15),   # LM backbone only (ViT stub excluded)
}


@pytest.mark.parametrize("name", sorted(EXPECTED_PARAMS_B))
def test_full_config_param_count(name):
    cfg = configs.get_config(name)
    params = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(params))
    expect, tol = EXPECTED_PARAMS_B[name]
    rel = abs(total / 1e9 - expect) / expect
    assert rel < tol, f"{name}: {total/1e9:.2f}B vs {expect}B (rel {rel:.2%})"


def test_shapes_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert len(applicable_shapes("mamba2-1.3b")) == 4
    assert len(applicable_shapes("gemma2-27b")) == 3          # long skipped
    total_cells = sum(len(applicable_shapes(n)) + (1 if n not in
                      ("mamba2-1.3b", "jamba-1.5-large-398b", "mixtral-8x7b")
                      else 0) for n in configs.ARCH_NAMES)
    assert total_cells == 40                                   # 10 × 4
