"""Detection serving (serve v2): batched W1A8 YOLO requests through the same
ServeRequest/Scheduler API as LM decode, verified against the float
reference within core.verify tolerances (paper §6.3 discipline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import verify
from repro.models import detection, yolo
from repro.serve import DetectionBackend, Scheduler, ServeRequest


@pytest.fixture(scope="module")
def detector():
    rng = np.random.default_rng(0)
    imgs_u8 = rng.integers(0, 256, (3, 320, 320, 3), np.uint8)
    params, art = yolo.build_detector(
        jax.random.PRNGKey(42), jnp.asarray(imgs_u8[:1], jnp.float32) / 256.0)
    return params, art, imgs_u8


@pytest.fixture(scope="module")
def served(detector):
    params, art, imgs_u8 = detector
    sched = Scheduler(DetectionBackend(art, slots=2))
    results = sched.run([ServeRequest(rid=i, image=imgs_u8[i])
                         for i in range(3)])         # 3 reqs > 2 slots
    return params, imgs_u8, sched, {r.rid: r for r in results}


def test_detection_serves_through_scheduler(served):
    _, _, sched, by_rid = served
    assert sorted(by_rid) == [0, 1, 2]
    assert all(r.finish_reason == "ok" for r in by_rid.values())
    s = sched.metrics.summary()
    assert s["images"] == 3 and s["requests_completed"] == 3
    assert s["ticks"] == 2                           # B=2 tick then B=1 tick
    assert s["img_per_s"] > 0 and s["tick_p95_ms"] > 0


def test_served_raw_head_matches_float_reference(served):
    """Raw head of the served (packed Pallas) path vs float oracle — the
    same Table-6 tolerances as the offline kernel-alignment test."""
    params, imgs_u8, _, by_rid = served
    ref = np.asarray(yolo.yolo_forward_float(
        params, jnp.asarray(imgs_u8, jnp.float32) / 256.0), np.float64)
    got = np.stack([by_rid[i].detections["raw"] for i in range(3)])
    rep = verify.compare("served_raw", got, ref, lsb=0.02)
    assert rep.max_abs < 0.02 and rep.within_1lsb == 1.0


def test_served_decoded_detections_match_float_reference(served):
    """Pre-NMS decoded detections (boxes + per-class scores, element-
    aligned) of the served path vs the float reference, core.verify
    statistics."""
    params, imgs_u8, _, by_rid = served
    ref = detection.decode_head(yolo.yolo_forward_float(
        params, jnp.asarray(imgs_u8, jnp.float32) / 256.0))
    got = detection.decode_head(jnp.stack(
        [by_rid[i].detections["raw"] for i in range(3)]))
    for leaf in ("boxes", "scores"):
        rep = verify.compare(f"served_{leaf}", np.asarray(got[leaf]),
                             np.asarray(ref[leaf]), lsb=1e-3)
        assert rep.max_abs < 1e-3 and rep.within_1lsb == 1.0, rep.row()


def _trained_regime_head():
    """Score-separated head: confident, class-separated peaks on a quiet
    background — the regime where NMS set equality is well-conditioned
    (untrained heads tie all 300 scores at σ(0)² ≈ 0.25)."""
    key = jax.random.PRNGKey(7)
    raw = jnp.full((1, 10, 10, 75), 0.0)
    r = raw.reshape(1, 10, 10, 3, 25)
    r = r.at[..., 4].set(-6.0)                       # background objectness
    peaks = [(1, 2, 0, 3), (4, 7, 1, 11), (8, 3, 2, 0),
             (5, 5, 0, 19), (9, 9, 1, 7), (2, 8, 2, 11)]
    for gy, gx, a, cls in peaks:
        r = r.at[0, gy, gx, a, 4].set(5.0)           # confident object
        r = r.at[0, gy, gx, a, 5:].set(-5.0)
        r = r.at[0, gy, gx, a, 5 + cls].set(4.0)     # separated class
        r = r.at[0, gy, gx, a, :4].set(
            jax.random.normal(jax.random.fold_in(key, gy * 10 + gx), (4,)))
    return r.reshape(1, 10, 10, 75), peaks, key


def test_nms_detections_stable_at_verified_tolerance():
    """NMS'd detections match between a head and a copy perturbed by 3×
    the raw-head tolerance the serving path is verified to (max_abs ≈ 3e-4
    in test_served_raw_head_matches_float_reference). Untrained heads tie
    all 300 scores at σ(0)² ≈ 0.25 (argmax of ties is ill-conditioned), so
    the equivalence is stated on a score-separated, trained-regime head:
    clear peaks in, identical detection sets out."""
    raw, peaks, key = _trained_regime_head()
    noise = 1e-3 * jax.random.uniform(key, raw.shape, minval=-1, maxval=1)
    rb, rs, rc = detection.postprocess(raw)
    pb, ps, pc = detection.postprocess(raw + noise)
    ref = detection.detections_to_list(rb[0], rs[0], rc[0])
    got = detection.detections_to_list(pb[0], ps[0], pc[0])
    assert len(ref) == len(got) == len(peaks)
    unmatched = list(ref)
    for d in got:
        for j, e in enumerate(unmatched):
            iou = float(detection.iou_cxcywh(
                jnp.asarray(d["box_cxcywh"]), jnp.asarray(e["box_cxcywh"])))
            if (d["class_id"] == e["class_id"] and iou > 0.95
                    and abs(d["score"] - e["score"]) < 0.01):
                unmatched.pop(j)
                break
        else:
            raise AssertionError(f"unmatched detection {d}")


def test_detections_to_list_drops_empty_slots():
    boxes = jnp.asarray([[0.5, 0.5, 0.2, 0.2], [0.0, 0.0, 0.0, 0.0]])
    dets = detection.detections_to_list(boxes, jnp.asarray([0.9, 0.0]),
                                        jnp.asarray([3, -1]))
    assert len(dets) == 1 and dets[0]["class_id"] == 3


def _match_detection_sets(ref, got, *, iou_min=0.9, score_tol=0.01):
    """Greedy bipartite match: every got-detection must pair with exactly
    one ref-detection of the same class, overlapping box, close score."""
    assert len(ref) == len(got), (len(ref), len(got))
    unmatched = list(ref)
    for d in got:
        for j, e in enumerate(unmatched):
            iou = float(detection.iou_cxcywh(
                jnp.asarray(d["box_cxcywh"]), jnp.asarray(e["box_cxcywh"])))
            if (d["class_id"] == e["class_id"] and iou > iou_min
                    and abs(d["score"] - e["score"]) < score_tol):
                unmatched.pop(j)
                break
        else:
            raise AssertionError(f"unmatched detection {d}")


def test_compact_wire_preserves_trained_regime_detection_set():
    """The device-NMS emission wire (fp16 boxes/scores, int8 classes, int32
    valid-count) carries the IDENTICAL detection set as the f32 NMS output
    on the score-separated trained-regime head — fp16 only rounds values
    the NMS already decided on in f32."""
    raw, peaks, _ = _trained_regime_head()
    b, s, c = detection.postprocess(raw)
    cb, cs, cc, valid = detection.compact_detections(b[0], s[0], c[0])
    assert cb.dtype == jnp.float16 and cs.dtype == jnp.float16
    assert cc.dtype == jnp.int8 and valid.dtype == jnp.int32
    ref = detection.detections_to_list(b[0], s[0], c[0])
    got = detection.detections_to_list(cb, cs, cc)
    assert int(valid) == len(ref) == len(peaks)
    _match_detection_sets(ref, got, iou_min=0.99, score_tol=1e-2)


def test_device_nms_serving_matches_host_wire_and_shrinks_sync(detector):
    """device_nms=True serves the same detection set as the raw-head wire
    (same executable runs the NMS; only the emission payload changes) with
    ≥ 10× fewer bytes per dispatch — the BENCH_serve headline claim."""
    _, art, imgs_u8 = detector

    def run(device_nms):
        backend = DetectionBackend(art, slots=2, device_nms=device_nms)
        results = Scheduler(backend).run(
            [ServeRequest(rid=i, image=imgs_u8[i]) for i in range(3)])
        return backend, {r.rid: r for r in results}

    host_backend, host = run(False)
    dev_backend, dev = run(True)
    bucket = host_backend.buckets[0]
    assert (host_backend._batch_bytes[bucket]
            / dev_backend._batch_bytes[bucket]) >= 10
    for rid in range(3):
        d = dev[rid].detections
        assert "raw" not in d and d["valid"] == int(np.sum(d["scores"] > 0))
        ref = detection.detections_to_list(*(host[rid].detections[k] for k
                                             in ("boxes", "scores",
                                                 "classes")))
        got = detection.detections_to_list(d["boxes"], d["scores"],
                                           d["classes"])
        _match_detection_sets(ref, got, iou_min=0.9, score_tol=0.01)


def test_host_sync_bytes_attributed_at_dispatch_tick(detector):
    """Satellite fix (PR 8): pipelined mode used to charge tick t with the
    bytes of the batch harvested from tick t−1. The payload of the
    fixed-width executable is static (jax.eval_shape), so bytes are now
    credited at the dispatch tick — the per-tick series is identical across
    depth 1/2 (depth 2's extra drain tick costs 0) and per-sync bytes are
    directly comparable."""
    _, art, imgs_u8 = detector

    def series(depth):
        backend = DetectionBackend(art, slots=2, depth=depth)
        backend.warmup()            # pre-count syncs ignored by the scheduler
        sched = Scheduler(backend)
        for i in range(3):
            sched.submit(ServeRequest(rid=i, image=imgs_u8[i]))
        per_tick = []
        while sched.queue or sched.active:
            before = sched.metrics.host_sync_bytes
            sched.tick()
            per_tick.append(sched.metrics.host_sync_bytes - before)
        return backend, sched.metrics.summary(), per_tick

    ss_backend, ss_sum, ss_series = series(depth=1)
    _, ov_sum, ov_series = series(depth=2)
    B = ss_backend._batch_bytes[ss_backend.buckets[0]]
    assert ss_series == [B, B]           # dispatch ticks carry the bytes...
    assert ov_series == [B, B, 0]        # ...and the drain tick carries none
    assert ss_sum["host_sync_bytes_per_sync"] == B
    assert ov_sum["host_sync_bytes_per_sync"] == B   # comparable across modes
