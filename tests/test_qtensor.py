"""QTensor: the one quantized codes+scale pytree every boundary speaks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qtensor import QTensor, S8_QMAX


def test_u8_roundtrip_within_half_step():
    x = jnp.linspace(0.0, 5.0, 257)
    qt = QTensor.quantize_u8(x, jnp.float32(5.0 / 255))
    assert qt.data.dtype == jnp.uint8
    err = jnp.abs(qt.dequantize() - x)
    assert float(jnp.max(err)) <= 0.5 * 5.0 / 255 + 1e-6


def test_s8_roundtrip_symmetric_straddles_zero():
    x = jnp.asarray([-3.0, -1e-4, 0.0, 1e-4, 2.9999, 3.0])
    qt = QTensor.quantize_s8(x)
    assert qt.data.dtype == jnp.int8
    assert int(qt.data[0]) == -S8_QMAX and int(qt.data[-1]) == S8_QMAX
    err = jnp.abs(qt.dequantize() - x)
    assert float(jnp.max(err)) <= 0.5 * 3.0 / S8_QMAX + 1e-7


def test_s8_explicit_shared_scale_respected():
    x = jnp.asarray([0.5, -0.25])
    qt = QTensor.quantize_s8(x, scale=jnp.float32(1.0 / S8_QMAX))
    np.testing.assert_array_equal(np.asarray(qt.data), [64, -32])


def test_b1_pack_dequantize_matches_signs():
    w = jax.random.normal(jax.random.PRNGKey(0), (70, 12))
    alpha = jnp.mean(jnp.abs(w), axis=0)
    qt = QTensor.pack_b1(w, alpha, axis=0)
    assert qt.data.dtype == jnp.uint32 and qt.kdim == 70
    want = np.where(np.asarray(w) >= 0, 1.0, -1.0) * np.asarray(alpha)
    np.testing.assert_allclose(np.asarray(qt.dequantize()), want, rtol=1e-6)


def test_pytree_roundtrip_and_jit_boundary():
    qt = QTensor.quantize_u8(jnp.arange(8.0), jnp.float32(0.05))
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2                      # data + scale trace/permute
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.qtype == "u8"

    @jax.jit
    def deq(q):
        return q.dequantize()

    np.testing.assert_allclose(np.asarray(deq(qt)),
                               np.asarray(qt.dequantize()))


def test_distinct_qtypes_have_distinct_treedefs():
    a = QTensor(jnp.zeros(4, jnp.int8), jnp.float32(1.0), "s8")
    b = QTensor(jnp.zeros(4, jnp.uint8), jnp.float32(1.0), "u8")
    ta = jax.tree_util.tree_structure(a)
    tb = jax.tree_util.tree_structure(b)
    assert ta != tb                              # wire format is structural


def test_wire_bytes_counts_payload_plus_scale():
    qt = QTensor.quantize_s8(jnp.ones((4, 8)))
    assert qt.wire_bytes() == 4 * 8 * 1 + 4      # int8 payload + f32 scale
    f = QTensor.from_f32(jnp.ones((4, 8)))
    assert f.wire_bytes() == 4 * 8 * 4 + 4


def test_unknown_qtype_rejected():
    with pytest.raises(ValueError, match="qtype"):
        QTensor(jnp.zeros(1), jnp.float32(1.0), "fp4")
