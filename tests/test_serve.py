"""Serving tests: decode≡forward consistency, ring cache, packed W1A8,
SP attention combine, continuous batching, and the serve-v2 scheduler
(stop tokens, per-request sampling, batched multi-row prefill)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import init_lm_params, lm_forward
from repro.serve import (LMBackend, SamplingParams, Scheduler, ServeEngine,
                         ServeRequest, cache_bytes, deploy_lm, generate,
                         init_cache, merge_rows, packed_param_bytes)
from repro.serve.batching import Request
from repro.serve.sp import sp_attention_local


def _greedy_via_forward(cfg, params, prompt, n, mode):
    """Oracle: re-run the full forward for every generated token."""
    toks = prompt
    out = []
    for _ in range(n):
        logits = lm_forward(cfg, params, toks, mode=mode)
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        out.append(nxt)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return jnp.stack(out, 1)


@pytest.mark.parametrize("name", ["chatglm3-6b", "mixtral-8x7b",
                                  "mamba2-1.3b", "jamba-1.5-large-398b",
                                  "gemma2-27b"])
def test_decode_matches_forward(name):
    """Incremental decode must reproduce teacher-forced greedy decoding."""
    cfg = configs.get_reduced(name)
    params = init_lm_params(jax.random.PRNGKey(5), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                cfg.vocab_size, jnp.int32)
    want = _greedy_via_forward(cfg, params, prompt, 5, "float")
    got = generate(cfg, params, prompt, max_new=5, max_len=32, mode="float")
    assert np.array_equal(np.asarray(got), np.asarray(want)), \
        f"{name}: decode {np.asarray(got)} vs forward {np.asarray(want)}"


def test_ring_cache_bounds_memory():
    cfg = configs.get_reduced("mixtral-8x7b")       # sliding_window=8
    cache = init_cache(cfg, 2, 128)
    for slot in cache["slots"]:
        if "k" in slot:
            assert slot["k"].shape[2] == 8          # ring = window < max_len


def test_ring_decode_long_context_consistent():
    """Decoding past the window with the ring cache matches full forward
    (the window mask makes distant tokens irrelevant)."""
    cfg = configs.get_reduced("mixtral-8x7b")
    params = init_lm_params(jax.random.PRNGKey(3), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                                cfg.vocab_size, jnp.int32)   # > window 8
    want = _greedy_via_forward(cfg, params, prompt, 4, "float")
    got = generate(cfg, params, prompt, max_new=4, max_len=64, mode="float")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_packed_deploy_matches_eval_and_shrinks():
    cfg = configs.get_reduced("qwen2.5-14b")
    params = init_lm_params(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0,
                              cfg.vocab_size, jnp.int32)
    ref = lm_forward(cfg, params, toks, mode="w1a8_eval")
    packed = deploy_lm(params)
    got = lm_forward(cfg, packed, toks, mode="w1a8_eval")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0, atol=2e-4)
    acct = packed_param_bytes(packed)
    assert acct["ratio"] > 3.0      # small model; big models → ~16×


def test_packed_bytes_ratio_full_config():
    """kimi-k2 FULL config: packed body ≈ 1 bit/weight ⇒ ≥12× smaller."""
    cfg = configs.get_config("kimi-k2-1t-a32b")
    shapes = jax.eval_shape(
        lambda: deploy_lm(init_lm_params(jax.random.PRNGKey(0), cfg)))
    packed_b = eq_b = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        packed_b += n
        eq_b += (int(np.prod(leaf.shape)) * 32 * 2 if "packed" in name
                 else int(np.prod(leaf.shape)) * 2)
    assert packed_b < 150e9, f"packed 1T model = {packed_b/1e9:.0f} GB"
    assert eq_b / packed_b > 12, f"ratio {eq_b/packed_b:.1f}"


def test_sp_attention_matches_dense():
    """Sharded partial-softmax combine == dense attention (math identity)."""
    b, h, kv, hd, t = 2, 8, 4, 16, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    cur = jnp.full((b,), 40)
    # dense reference
    o_ref, m_ref, l_ref = sp_attention_local(q, k, v, pos, cur)
    o_ref = o_ref / l_ref[..., None]
    # two shards combined manually
    o1, m1, l1 = sp_attention_local(q, k[:, :32], v[:, :32], pos[:, :32], cur)
    o2, m2, l2 = sp_attention_local(q, k[:, 32:], v[:, 32:], pos[:, 32:], cur)
    m = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    o = (o1 * jnp.exp(m1 - m)[..., None] + o2 * jnp.exp(m2 - m)[..., None]) \
        / l[..., None]
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)


def test_ring_decode_wraparound_past_window():
    """Ring writes wrap pos % L several times past the window boundary;
    decode must still match the full (window-masked) forward."""
    cfg = configs.get_reduced("mixtral-8x7b")        # sliding_window=8
    params = init_lm_params(jax.random.PRNGKey(9), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(10), (1, 5), 0,
                                cfg.vocab_size, jnp.int32)
    n = 16                                           # pos reaches 20 = 2.5 rings
    want = _greedy_via_forward(cfg, params, prompt, n, "float")
    got = generate(cfg, params, prompt, max_new=n, max_len=64, mode="float")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_cache_single_module_ring_and_bytes():
    """One cache module: init_cache is ring-aware and cache_bytes reflects
    the window-bounded (not max_len-bounded) KV footprint."""
    cfg = configs.get_reduced("mixtral-8x7b")        # sliding_window=8
    ring = cache_bytes(cfg, 2, 256)
    assert ring == cache_bytes(cfg, 2, 8192)         # bounded by the window
    pool = init_cache(cfg, 3, 32)
    fresh = init_cache(cfg, 2, 32)
    fresh = {"slots": fresh["slots"],
             "lengths": jnp.asarray([7, 9], jnp.int32)}
    merged = merge_rows(pool, fresh, [2, 0])
    assert merged["lengths"].tolist() == [9, 0, 7]


def test_scheduler_stop_token_terminates_early():
    """SamplingParams.stop_tokens ends decode before max_new (regression:
    requests used to always run to max_new)."""
    cfg = configs.get_reduced("granite-20b")
    params = init_lm_params(jax.random.PRNGKey(6), cfg)
    prompt = [1, 2, 3]
    oracle = [int(t) for t in _greedy_via_forward(
        cfg, params, jnp.asarray(prompt, jnp.int32)[None], 6, "float")[0]]
    stop = oracle[2]
    expect = oracle[:oracle.index(stop) + 1]
    sched = Scheduler(LMBackend(cfg, params, slots=2, max_len=32))
    [res] = sched.run([ServeRequest(rid=0, prompt=prompt,
                                    sampling=SamplingParams(
                                        max_new=6, stop_tokens=(stop,)))])
    assert res.finish_reason == "stop"
    assert res.tokens == expect and len(res.tokens) < 6


def test_scheduler_equivalence_continuous_vs_sequential():
    """Property: continuous-batched greedy outputs ≡ one-request-at-a-time
    generate, across mixed prompt lengths (grouped multi-row prefill) and
    slot recycling (6 requests through a 3-slot pool)."""
    cfg = configs.get_reduced("granite-20b")
    params = init_lm_params(jax.random.PRNGKey(6), cfg)
    prompts = [[1 + i, 2, 3] if i % 2 == 0 else [4, 1 + i, 2, 5]
               for i in range(6)]
    sched = Scheduler(LMBackend(cfg, params, slots=3, max_len=32))
    results = sched.run([ServeRequest(rid=i, prompt=p,
                                      sampling=SamplingParams(max_new=4))
                         for i, p in enumerate(prompts)])
    assert len(results) == 6
    by_rid = {r.rid: r for r in results}
    for i, p in enumerate(prompts):
        want = _greedy_via_forward(
            cfg, params, jnp.asarray(p, jnp.int32)[None], 4, "float")[0]
        assert by_rid[i].tokens == [int(t) for t in want], (i, by_rid[i])
        assert by_rid[i].finish_reason == "length"
    s = sched.metrics.summary()
    assert s["requests_completed"] == 6 and s["tokens"] == 24
    assert 0 < s["batch_occupancy"] <= 1 and s["tick_p95_ms"] >= 0


def test_scheduler_per_request_temperature():
    """Greedy and sampled requests coexist in one pool; the greedy row must
    stay bit-identical to its standalone generation."""
    cfg = configs.get_reduced("granite-20b")
    params = init_lm_params(jax.random.PRNGKey(6), cfg)
    sched = Scheduler(LMBackend(cfg, params, slots=2, max_len=32))
    reqs = [ServeRequest(rid=0, prompt=[1, 2, 3],
                         sampling=SamplingParams(max_new=5)),
            ServeRequest(rid=1, prompt=[3, 2, 1],
                         sampling=SamplingParams(max_new=5,
                                                 temperature=1.0))]
    by_rid = {r.rid: r for r in sched.run(reqs)}
    want = _greedy_via_forward(cfg, params,
                               jnp.asarray([[1, 2, 3]], jnp.int32), 5,
                               "float")[0]
    assert by_rid[0].tokens == [int(t) for t in want]
    assert len(by_rid[1].tokens) == 5


def test_continuous_batching_engine(monkeypatch):
    cfg = configs.get_reduced("granite-20b")
    params = init_lm_params(jax.random.PRNGKey(6), cfg)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3], max_new=4)
            for i in range(5)]                       # 5 reqs > 3 slots
    from repro.serve import batching
    monkeypatch.setattr(batching, "_deprecation_warned", False)  # re-arm
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(cfg, params, slots=3, max_len=32)
    done = eng.run(list(reqs))
    assert all(r.done and len(r.out) == 4 for r in done)
    # each request's output must equal its standalone greedy generation
    for r in reqs[:2]:
        prompt = jnp.asarray(r.prompt, jnp.int32)[None]
        want = _greedy_via_forward(cfg, params, prompt, 4, "float")[0]
        assert np.array_equal(np.asarray(r.out), np.asarray(want)), \
            (r.out, np.asarray(want))


def test_encdec_generate_seamless():
    cfg = configs.get_reduced("seamless-m4t-medium")
    params = init_lm_params(jax.random.PRNGKey(7), cfg)
    feats = jax.random.normal(jax.random.PRNGKey(8), (1, 6, cfg.d_model)) * 0.1
    toks = jnp.asarray([[3, 5, 7]], jnp.int32)
    logits = lm_forward(cfg, params, toks, mode="float",
                        encoder_embeds=feats)
    assert logits.shape == (1, 3, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
