"""Paper-model tests: Table-1 structure, 0.74M/0.098G claims, path alignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import verify
from repro.models import detection, yolo


@pytest.fixture(scope="module")
def calibrated():
    params = yolo.init_yolo_params(jax.random.PRNGKey(42))
    img_u8 = jax.random.randint(jax.random.PRNGKey(1), (1, 320, 320, 3),
                                0, 256, jnp.int32).astype(jnp.uint8)
    img = img_u8.astype(jnp.float32) / 256.0
    params = yolo.calibrate_yolo(params, img)
    return params, img_u8, img


def test_param_count_matches_paper():
    counts = yolo.count_params()
    assert counts["weights"] == 736880           # 0.74 M (Table 5)
    assert abs(counts["total"] / 1e6 - 0.74) < 0.01


def test_gflops_matches_paper_convention():
    g = yolo.count_gflops()
    assert abs(g["paper_gflops"] - 0.098) / 0.098 < 0.05, g
    assert g["total_gflops"] > 1.0               # face-value incl. binary ops


def test_spatial_progression_table2():
    sizes = yolo.spatial_sizes()
    assert sizes["conv1"] == 320 and sizes["conv2"] == 160
    assert sizes["conv5"] == 20 and sizes["conv8"] == 10
    assert sizes["conv11"] == 10


def test_float_forward_shape_and_finite(calibrated):
    params, _, img = calibrated
    out = yolo.yolo_forward_float(params, img, train=False)
    assert out.shape == (1, 10, 10, 75)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_train_mode_grads_flow(calibrated):
    params, _, img = calibrated

    def loss(p):
        return jnp.mean(yolo.yolo_forward_float(p, img, train=True) ** 2)

    grads = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # latent binary weights must receive gradient (STE)
    assert float(jnp.sum(jnp.abs(grads["conv5"]["w"]))) > 0
    assert float(jnp.sum(jnp.abs(grads["conv5"]["act_step"]))) > 0


def test_int_pipeline_alignment(calibrated):
    """Paper §6.3 / Table 6: integer datapath vs float oracle."""
    params, img_u8, img = calibrated
    out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                       np.float64)
    art = yolo.deploy_yolo(params)
    out_i = yolo.yolo_forward_int(art, np.asarray(img_u8)) / 2.0 ** 15
    rep = verify.compare("final_raw", out_i, out_f, lsb=0.02)
    # random-init absolute errors are far below the paper's trained-model
    # numbers (max 0.109 / MAE 0.020); corr needs trained dynamic range.
    assert rep.max_abs < 0.02
    assert rep.mean_abs < 0.002
    assert rep.within_1lsb == 1.0


def test_kernel_path_alignment(calibrated):
    params, _, img = calibrated
    out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                       np.float64)
    kart = yolo.deploy_yolo_kernel(params)
    out_k = np.asarray(yolo.yolo_forward_kernel(kart, img, interpret=True),
                       np.float64)
    rep = verify.compare("kernel_raw", out_k, out_f, lsb=0.02)
    assert rep.max_abs < 0.02 and rep.within_1lsb == 1.0


def test_kernel_path_popcount_alignment(calibrated):
    """Binary-domain (XNOR-popcount) serving forward on a per-tensor
    calibrated artifact: within the same §6.3 envelope vs the float oracle,
    and within the dot path's own bf16 prologue noise of the dot path
    (popcount is the exact one — the only difference IS that noise)."""
    params, _, img = calibrated
    pt = yolo.calibrate_yolo(params, img, per_channel=False)
    out_f = np.asarray(yolo.yolo_forward_float(pt, img, train=False),
                       np.float64)
    kart = yolo.deploy_yolo_kernel(pt)
    out_pc = np.asarray(yolo.yolo_forward_kernel(
        kart, img, interpret=True, accum="popcount"), np.float64)
    rep = verify.compare("kernel_raw_popcount", out_pc, out_f, lsb=0.02)
    assert rep.max_abs < 0.02 and rep.within_1lsb == 1.0
    out_dot = np.asarray(yolo.yolo_forward_kernel(
        kart, img, interpret=True, accum="dot"), np.float64)
    assert np.abs(out_pc - out_dot).max() < 0.02


def test_popcount_serves_per_channel_artifact(calibrated):
    """A per-channel calibrated artifact serves through the popcount path
    (fused and unfused pool alike) inside the §6.3 envelope: the producer
    epilogue re-quantizes each popcount consumer's boundary onto the
    uniformized step s̄ = max_c(s_c) (DESIGN.md §16), so no host-side
    uniform-step rejection exists anymore — per_channel=True is simply a
    coarser boundary grid, not a different datapath."""
    params, _, img = calibrated
    kart = yolo.deploy_yolo_kernel(params)       # per-channel calibrated
    out_f = np.asarray(yolo.yolo_forward_float(params, img, train=False),
                       np.float64)
    for fuse_pool in (False, True):
        out_pc = np.asarray(yolo.yolo_forward_kernel(
            kart, img, interpret=True, accum="popcount",
            fuse_pool=fuse_pool), np.float64)
        rep = verify.compare(f"kernel_raw_popcount_perch_fp{fuse_pool}",
                             out_pc, out_f, lsb=0.02)
        assert rep.max_abs < 0.02 and rep.within_1lsb == 1.0


def test_int_pipeline_is_deterministic(calibrated):
    params, img_u8, _ = calibrated
    art = yolo.deploy_yolo(params)
    a = yolo.yolo_forward_int(art, np.asarray(img_u8))
    b = yolo.yolo_forward_int(art, np.asarray(img_u8))
    assert np.array_equal(a, b)                  # bit-exact, like RTL


def test_detection_decode_and_nms():
    raw = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 10, 75)) * 2.0
    boxes, scores, cls = detection.postprocess(raw, max_out=20)
    assert boxes.shape == (2, 20, 4) and cls.shape == (2, 20)
    assert bool(jnp.all(scores >= 0)) and bool(jnp.all(scores <= 1))
    # boxes with positive score have valid geometry
    ok = (boxes[..., 2] >= 0) & (boxes[..., 3] >= 0)
    assert bool(jnp.all(jnp.where(scores > 0, ok, True)))


def test_nms_suppresses_duplicates():
    # two near-identical boxes, one weaker: NMS must keep exactly one
    boxes = jnp.asarray([[0.5, 0.5, 0.2, 0.2], [0.51, 0.5, 0.2, 0.2],
                         [0.9, 0.9, 0.1, 0.1]])
    scores = jnp.zeros((3, 20)).at[0, 3].set(0.9).at[1, 3].set(0.8) \
                               .at[2, 7].set(0.7)
    ob, os_, oc = detection.nms(boxes, scores, max_out=3)
    kept = int(jnp.sum(os_ > 0))
    assert kept == 2
    assert int(oc[0]) == 3 and int(oc[1]) == 7
