"""Multi-device correctness checks, run in a fresh process with 16 virtual
devices (tests/test_dist.py shells out to this). Asserts:

  1. MoE EP all-to-all path ≡ single-device reference
  2. TP-in-expert (psum) ≡ reference, incl. QAT α pmean
  3. GPipe pipeline ≡ sequential stage application
  4. int8-quantized all-reduce ≈ exact mean (< 1% rel err)
  5. sharded W1A8 train step ≡ single-device step (same loss)
  6. SP (context-parallel) decode attention ≡ dense attention
  7. 1F1B/GPipe pipelined *training* ≡ sequential jax.grad oracle
     (loss + grads ≤ 1e-5 rel err), int8-wire DP grads in envelope
  8. pipelined LM train step (train/step.make_pipeline_train_step)
     ≡ single-device make_train_step (same loss)
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                           + os.environ.get("XLA_FLAGS", ""))

import dataclasses  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.dist.collectives import tree_quantized_allreduce  # noqa: E402
from repro.dist.pipeline import (gpipe, pipeline_train_reference,  # noqa: E402
                                 pipeline_train_step)
from repro.dist import sharding as shard_rules  # noqa: E402
from repro.models import moe as moe_mod  # noqa: E402
from repro.models.layers import ModelConfig  # noqa: E402
from repro.models.transformer import ShardCtx, init_lm_params  # noqa: E402
from repro.optim import sgdm  # noqa: E402
from repro.train.step import (make_pipeline_train_step,  # noqa: E402
                              make_train_step)


def check_moe_ep():
    cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      num_experts=4, top_k=2, capacity_factor=4.0,
                      w1a8_body=True)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    for mode in ("float", "w1a8_train"):
        y_ref = moe_mod.moe_ffn(p, cfg, x, mode=mode, ep_axis=None)
        mesh = jax.make_mesh((4, 4), ("data", "model"))

        def inner(pl, xl):
            return moe_mod.moe_ffn(pl, cfg, xl, mode=mode, ep_axis="data",
                                   tp_axis="model")
        specs = {"router": P(None, None), "up": P("data", None, "model"),
                 "gate": P("data", None, "model"),
                 "down": P("data", "model", None), "act_step": P()}
        with mesh:
            y = jax.jit(jax.shard_map(
                inner, mesh=mesh, in_specs=(specs, P("data", None)),
                out_specs=P("data", None), check_vma=False))(p, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 2e-5, f"moe ep ({mode}): {err}"
    print("1/2. MoE EP+TP (float & QAT) OK")


def check_gpipe():
    mesh = jax.make_mesh((4, 4), ("pod", "model"))
    n_stages, num_micro, mb, d = 4, 8, 2, 16
    ws = jax.random.normal(jax.random.PRNGKey(2), (n_stages, d, d)) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(3), (num_micro, mb, d))
    want = x
    for i in range(n_stages):
        want = jax.vmap(lambda xm: stage_fn(ws[i], xm))(want)
    f = gpipe(stage_fn, mesh=mesh, axis="pod", num_micro=num_micro)
    with mesh:
        got = f(ws, x)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, f"gpipe: {err}"
    print("3. GPipe pipeline OK")


def check_quantized_allreduce():
    mesh = jax.make_mesh((16,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(4), (16, 64, 64))

    def inner(gl):
        return tree_quantized_allreduce({"g": gl[0]}, "data")["g"]

    with mesh:
        out = jax.jit(jax.shard_map(inner, mesh=mesh,
                                    in_specs=(P("data", None, None),),
                                    out_specs=P(), check_vma=False))(g)
    want = jnp.mean(g, axis=0)
    rel = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
    # int8 wire format carries ~1% relative noise on unit-normal grads —
    # the bandwidth/precision trade documented in dist/collectives.py
    assert rel < 0.03, f"quantized allreduce rel err {rel}"
    print(f"4. int8 all-reduce OK (rel err {rel:.4f})")


def check_sharded_train_step():
    cfg = dataclasses.replace(configs.get_reduced("mixtral-8x7b"),
                              num_experts=4, d_ff=64)
    params = init_lm_params(jax.random.PRNGKey(5), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (8, 16), 0,
                              cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    opt = sgdm(1e-2)
    s_ref = make_train_step(cfg, opt, remat=False)
    _, _, m_ref = s_ref(params, opt[0](params), batch)

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                   ep_axis="data")
    p_sh = shard_rules.tree_shardings(params, cfg, mesh)
    o_sh = shard_rules.tree_shardings(opt[0](params), cfg, mesh)
    b_sh = {"tokens": NamedSharding(mesh, P("data", None)),
            "labels": NamedSharding(mesh, P("data", None))}
    s_dist = jax.jit(make_train_step(cfg, opt, remat=True, ctx=ctx,
                                     microbatches=2),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None))
    with mesh:
        _, _, m = s_dist(jax.device_put(params, p_sh),
                         jax.device_put(opt[0](params), o_sh),
                         jax.device_put(batch, b_sh))
    diff = abs(float(m["loss"]) - float(m_ref["loss"]))
    assert diff < 5e-3, f"sharded train loss diff {diff}"
    print(f"5. sharded train step OK (loss diff {diff:.2e})")


def _tree_rel_err(got, want) -> float:
    d = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in
                     zip(jax.tree_util.tree_leaves(got),
                         jax.tree_util.tree_leaves(want))))
    n = jnp.sqrt(sum(jnp.sum(b ** 2)
                     for b in jax.tree_util.tree_leaves(want)))
    return float(d / n)


def check_pipeline_train():
    mesh = jax.make_mesh((4, 4), ("stage", "data"))
    n, num_micro, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(8)
    ws = {"w": jax.random.normal(key, (n, d, d)) * 0.3,
          "b": jax.random.normal(jax.random.fold_in(key, 1), (n, d)) * 0.1}
    top = {"head": jax.random.normal(jax.random.fold_in(key, 2),
                                     (d, d)) * 0.2}

    def stage_fn(w, x):
        return jnp.tanh(x @ w["w"] + w["b"])

    def loss_fn(tp, y, aux):
        return jnp.mean((y @ tp["head"] - aux["tgt"]) ** 2)

    x = jax.random.normal(jax.random.fold_in(key, 3), (num_micro, mb, d))
    aux = {"tgt": jax.random.normal(jax.random.fold_in(key, 4),
                                    (num_micro, mb, d))}
    l_ref, g_ref, gt_ref, dx_ref = pipeline_train_reference(
        stage_fn, loss_fn, ws, x, aux=aux, top=top)
    for sched in ("1f1b", "gpipe"):
        f = pipeline_train_step(stage_fn, loss_fn, mesh=mesh, axis="stage",
                                num_micro=num_micro, schedule=sched)
        with mesh:
            loss, gws, gtop, dx = f(ws, x, aux=aux, top=top)
        rel = max(_tree_rel_err(gws, g_ref), _tree_rel_err(gtop, gt_ref),
                  _tree_rel_err(dx, dx_ref),
                  abs(float(loss) - float(l_ref)) / abs(float(l_ref)))
        assert rel < 1e-5, f"pipeline train ({sched}): rel err {rel}"

    # DP composition: mb shards over 'data', grads ride the int8 wire
    x = jax.random.normal(jax.random.fold_in(key, 5), (num_micro, 8, d))
    aux = {"tgt": jax.random.normal(jax.random.fold_in(key, 6),
                                    (num_micro, 8, d))}
    ref = pipeline_train_reference(stage_fn, loss_fn, ws, x, aux=aux,
                                   top=top)
    for wire, tol in (("fp32", 1e-5), ("int8", 0.03)):
        f = pipeline_train_step(stage_fn, loss_fn, mesh=mesh, axis="stage",
                                num_micro=num_micro, dp_axis="data",
                                grad_wire=wire)
        with mesh:
            loss, gws, gtop, _ = f(ws, x, aux=aux, top=top)
        rel = max(_tree_rel_err(gws, ref[1]), _tree_rel_err(gtop, ref[2]))
        assert abs(float(loss) - float(ref[0])) < 1e-5, (wire, loss)
        assert rel < tol, f"pipeline train dp ({wire}): rel err {rel}"

    # int8 activation/cotangent wire on the stage-boundary permutes
    for sched in ("1f1b", "gpipe"):
        f = pipeline_train_step(stage_fn, loss_fn, mesh=mesh, axis="stage",
                                num_micro=num_micro, dp_axis="data",
                                schedule=sched, act_wire="int8")
        with mesh:
            loss, gws, gtop, _ = f(ws, x, aux=aux, top=top)
        rel = max(_tree_rel_err(gws, ref[1]), _tree_rel_err(gtop, ref[2]))
        assert abs(float(loss) - float(ref[0])) / abs(float(ref[0])) < 0.02, \
            (sched, loss)
        assert rel < 0.05, f"pipeline train act_wire ({sched}): rel err {rel}"
    print("7. 1F1B/GPipe pipelined training ≡ jax.grad oracle OK "
          "(int8-wire DP grads + int8 stage-permute acts in envelope)")


def check_pipeline_lm_train_step():
    import dataclasses
    cfg = dataclasses.replace(configs.get_reduced("qwen2.5-14b"))
    params = init_lm_params(jax.random.PRNGKey(9), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(10), (16, 16), 0,
                              cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    opt = sgdm(1e-2)
    s_ref = make_train_step(cfg, opt, remat=False)
    _, _, m_ref = s_ref(params, opt[0](params), batch)

    mesh = jax.make_mesh((8, 2), ("data", "stage"))
    p_sh = shard_rules.pipeline_tree_shardings(params, mesh,
                                               cfg.num_layers)
    s_pipe = jax.jit(make_pipeline_train_step(cfg, opt, mesh=mesh,
                                              num_micro=2,
                                              grad_wire="int8"))
    with mesh:
        _, _, m = s_pipe(jax.device_put(params, p_sh),
                         jax.device_put(opt[0](params),
                                        shard_rules.pipeline_tree_shardings(
                                            opt[0](params), mesh,
                                            cfg.num_layers)),
                         batch)
    diff = abs(float(m["loss"]) - float(m_ref["loss"]))
    assert diff < 5e-3, f"pipelined LM train loss diff {diff}"
    print(f"8. pipelined LM train step OK (loss diff {diff:.2e})")


def check_sp_attention():
    from repro.serve.sp import sp_decode_attention
    mesh = jax.make_mesh((16,), ("data",))
    b, h, kv, hd, t = 2, 8, 4, 16, 64
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (b, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    cur = jnp.full((b,), 40)
    from repro.serve.sp import sp_attention_local
    o_ref, m_ref, l_ref = sp_attention_local(q, k, v, pos, cur)
    o_ref = o_ref / l_ref[..., None]
    with mesh:
        got = sp_decode_attention(mesh, "data", q, k, v, pos, cur)
    err = float(jnp.max(jnp.abs(got - o_ref)))
    assert err < 1e-5, f"sp attention: {err}"
    print("6. SP decode attention OK")


if __name__ == "__main__":
    check_moe_ep()
    check_gpipe()
    check_quantized_allreduce()
    check_sharded_train_step()
    check_sp_attention()
    check_pipeline_train()
    check_pipeline_lm_train_step()
    print("ALL DIST CHECKS PASSED")
