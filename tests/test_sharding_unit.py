"""Single-process sharding-rule guard (fast CPU — no subprocess, no second
jax runtime). Catches sharding regressions that would otherwise only show up
in the 16-device subprocess suite (tests/test_dist.py).

Covers, for every config in ``configs.ARCH_NAMES``:
  * tree_shardings assigns a NamedSharding to every param leaf (1-device mesh)
  * every spec is *legal* on the production-sized 16×16 mesh: a mesh axis is
    only placed on a dim it divides, and used at most once per spec
  * the model axis actually lands on the big projections (not all-replicate)
  * optimizer (adamw) and packed-deploy trees inherit legal specs
"""
import jax
import pytest
from conftest import FakeProdMesh

from repro import configs
from repro.dist import sharding as shard_rules
from repro.dist.sharding import dp_axes, param_spec
from repro.models.transformer import init_lm_params


def _params_sds(name):
    cfg = configs.get_config(name)
    return cfg, jax.eval_shape(
        lambda c=cfg: init_lm_params(jax.random.PRNGKey(0), c))


def _assert_legal(path, shape, spec, mesh):
    used = []
    entries = tuple(spec)
    assert len(entries) <= len(shape), (path, shape, spec)
    for dim, ax in enumerate(entries):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        for a in axes:
            assert a in mesh.axis_names, (path, spec)
            assert shape[dim] % mesh.shape[a] == 0, \
                f"{path}: dim {dim} of {shape} not divisible by |{a}|"
            used.append(a)
    assert len(used) == len(set(used)), f"{path}: axis reused in {spec}"


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_every_param_leaf_gets_a_sharding(name):
    cfg, sds = _params_sds(name)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = shard_rules.tree_shardings(sds, cfg, mesh)
    n_params = len(jax.tree_util.tree_leaves(sds))
    shardings = jax.tree_util.tree_leaves(sh)
    assert len(shardings) == n_params
    assert all(isinstance(s, jax.sharding.NamedSharding) for s in shardings)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_specs_legal_on_production_mesh(name):
    cfg, sds = _params_sds(name)
    mesh = FakeProdMesh()
    for p, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]:
        path = jax.tree_util.keystr(p)
        spec = param_spec(path, leaf.shape, cfg, mesh)
        _assert_legal(path, leaf.shape, spec, mesh)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_model_axis_lands_on_projections(name):
    """At least one weight matrix per arch must be model-sharded; MoE archs
    must additionally shard an expert stack over (data, model)."""
    cfg, sds = _params_sds(name)
    mesh = FakeProdMesh()
    specs = {jax.tree_util.keystr(p):
             param_spec(jax.tree_util.keystr(p), leaf.shape, cfg, mesh)
             for p, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]}
    assert any("model" in str(s) for s in specs.values()), \
        f"{name}: everything replicated"
    if cfg.num_experts:
        moe = {k: s for k, s in specs.items() if "['moe']" in k}
        assert any("model" in str(s) for s in moe.values()), \
            f"{name}: expert hidden dims not TP sharded"
        if cfg.num_experts % mesh.shape["data"] == 0:
            assert any("data" in str(s) and "model" in str(s)
                       for s in moe.values()), \
                f"{name}: experts not EP+TP sharded"


def test_optimizer_and_packed_trees_inherit_legal_specs():
    from repro.optim import adamw
    from repro.serve.packed import deploy_lm

    cfg, sds = _params_sds("mixtral-8x7b")
    mesh = FakeProdMesh()
    opt_sds = jax.eval_shape(adamw(1e-3)[0], sds)
    packed_sds = jax.eval_shape(deploy_lm, sds)
    for tree in (opt_sds, packed_sds):
        for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            path = jax.tree_util.keystr(p)
            spec = param_spec(path, leaf.shape, cfg, mesh)
            _assert_legal(path, leaf.shape, spec, mesh)
    # packed column-parallel weights stay model-sharded on the word dim's N
    flat = {jax.tree_util.keystr(p): leaf for p, leaf
            in jax.tree_util.tree_flatten_with_path(packed_sds)[0]}
    wq_packed = next(k for k in flat if "['wq']['w_packed']" in k)
    assert "model" in str(param_spec(wq_packed, flat[wq_packed].shape,
                                     cfg, mesh))


def test_dp_axes():
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    assert dp_axes(mesh1) == ("data",)

    class Pod:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert dp_axes(Pod()) == ("pod", "data")
