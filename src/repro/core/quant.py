"""W1A8 quantization primitives (paper §3.2, Eqs. 3-1..3-4).

Weights:      w_b = sign(w) ∈ {-1,+1}, straight-through estimator in training.
Activations:  q_a = clip(round(x / s_a), 0, 255)  (LSQ — learned step size).

The inference graph carries two channel-indexed scales:
  Mul_prev    — indexed by *input* channel  (previous layer's dequant step)
  Div_current — indexed by *output* channel (current layer's quant step)
Fusing them into one constant would collapse per-input-channel information;
the paper fuses Mul_prev into the accumulation (Eq. 3-4) and applies
Div_current in the post-processing epilogue. `core/w1a8.py` and the Pallas
kernels preserve exactly that split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACT_QMAX = 255  # uint8 activations, ReLU-style non-negative range [0, 255]


# ---------------------------------------------------------------------------
# Eq. 3-1: weight binarization with STE
# ---------------------------------------------------------------------------

def binarize_weight(w: jax.Array) -> jax.Array:
    """sign(w) ∈ {-1,+1} (0 maps to +1, matching RTL sign-bit convention)."""
    return jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)


@jax.custom_vjp
def binarize_ste(w: jax.Array) -> jax.Array:
    """Binarize with straight-through estimator, clipped to |w|<=1 region.

    Forward: sign(w).  Backward: dL/dw = dL/dw_b * 1[|w| <= 1]
    (the standard BNN/XNOR-Net STE with saturation clipping).
    """
    return binarize_weight(w)


def _binarize_fwd(w):
    return binarize_weight(w), w


def _binarize_bwd(w, g):
    return (g * (jnp.abs(w) <= 1.0).astype(g.dtype),)


binarize_ste.defvjp(_binarize_fwd, _binarize_bwd)


# ---------------------------------------------------------------------------
# Eq. 3-3: LSQ activation quantization (uint8, non-negative)
# ---------------------------------------------------------------------------

def round_half_away(x: jax.Array) -> jax.Array:
    """Round half away from zero — matches the paper's RTL rounding."""
    return jnp.trunc(x + jnp.where(x >= 0, 0.5, -0.5))


def quantize_act(x: jax.Array, step: jax.Array) -> jax.Array:
    """q = clip(round(x / s), 0, 255) → uint8-valued float (dtype preserved)."""
    return jnp.clip(round_half_away(x / step), 0, ACT_QMAX)


def dequantize_act(q: jax.Array, step: jax.Array) -> jax.Array:
    return q * step


@jax.custom_vjp
def lsq_fake_quant(x: jax.Array, step: jax.Array, grad_scale: jax.Array):
    """LSQ fake-quantization: forward quant-dequant; backward trains `step`.

    Gradients follow Esser et al. (ICLR 2020):
      d q̂/d s = (q - x/s) inside the range, {0, QMAX} at the clip rails,
      scaled by grad_scale = 1/sqrt(numel * QMAX).
    d q̂/d x = 1 inside the range, 0 outside (STE with clipping).
    """
    return dequantize_act(quantize_act(x, step), step)


def _lsq_fwd(x, step, grad_scale):
    return lsq_fake_quant(x, step, grad_scale), (x, step, grad_scale)


def _reduce_to_shape(g: jax.Array, shape) -> jax.Array:
    """Sum-reduce ``g`` down to broadcast shape ``shape`` (per-channel steps)."""
    if g.shape == tuple(shape):
        return g
    ndiff = g.ndim - len(shape)
    axes = tuple(range(ndiff)) + tuple(
        i + ndiff for i, s in enumerate(shape) if s == 1 and g.shape[i + ndiff] != 1)
    return jnp.sum(g, axis=axes).reshape(shape)


def _lsq_bwd(res, g):
    x, step, grad_scale = res
    xs = x / step
    q = jnp.clip(round_half_away(xs), 0, ACT_QMAX)
    in_range = (xs >= 0) & (xs <= ACT_QMAX)
    dx = g * in_range.astype(g.dtype)
    # In-range: d(q̂)/ds = q - x/s.  At the rails: q̂ = rail*s so d/ds = rail (= q).
    dstep_elem = jnp.where(in_range, q - xs, q)
    dstep = _reduce_to_shape(g * dstep_elem, step.shape) * grad_scale
    return dx, dstep.astype(step.dtype), None


lsq_fake_quant.defvjp(_lsq_fwd, _lsq_bwd)


def lsq_grad_scale(numel: int) -> float:
    """LSQ gradient scale g = 1/sqrt(N * Q_max).

    Pure-Python math: this runs inside traced scan bodies where any jnp op
    would be staged (omnistaging) and poison the static value.
    """
    return float(numel * ACT_QMAX) ** -0.5


def init_step_from_batch(x: jax.Array) -> jax.Array:
    """LSQ init: s0 = 2*mean(|x|)/sqrt(QMAX)."""
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(jnp.asarray(ACT_QMAX, x.dtype))


def requant_epilogue(y: jax.Array, out_step: float,
                     out_dtype=jnp.uint8) -> jax.Array:
    """Requantize an f32 post-scale accumulator to next-layer uint8 codes.

    q = clip(round_half_away(y / s_out), 0, 255) — the Eq. 3-3 epilogue the
    conv, fused conv+pool, and matmul kernels all apply after Div_current
    and bias. One definition so the three paths cannot drift in rounding.
    """
    q = round_half_away(y / out_step)
    return jnp.clip(q, 0, ACT_QMAX).astype(out_dtype)


def fold_codes_to_uniform_step(a_u8: jax.Array,
                               mul_prev: jax.Array) -> tuple:
    """(codes, per-input-channel steps) → (codes', uniform scalar step m̄).

    The XNOR-popcount accumulation contracts bit planes against packed
    sign words — a per-input-channel Mul_prev cannot ride inside the
    bit-packed tree (Σ_k s_k·m_k·a_k does not factor out of the popcount).
    Instead the codes are requantized onto the coarsest channel's grid,
    m̄ = max_k m_k:

        a'_k = clip(round(a_k · m_k / m̄), 0, 255),   value ≈ a'_k · m̄

    and the single m̄ folds into Div_current exactly like the RTL's
    scale-into-the-accumulator discipline. No clipping ever engages
    (m_k/m̄ ≤ 1), and when the steps are already uniform the ratio is
    exactly 1.0 in IEEE arithmetic, so the fold is a bit-exact identity —
    preserving the popcount-vs-dot bit-exactness contract. ``mul_prev``
    broadcasts against the trailing axis of ``a_u8``.
    """
    m = mul_prev.astype(jnp.float32)
    mbar = jnp.maximum(jnp.max(m), 1e-20)
    codes = jnp.clip(round_half_away(a_u8.astype(jnp.float32) * (m / mbar)),
                     0, ACT_QMAX).astype(jnp.uint8)
    return codes, mbar


# ---------------------------------------------------------------------------
# Eq. 3-2 / 3-4: sign-controlled accumulation (reference semantics)
# ---------------------------------------------------------------------------

def sign_accumulate(acts: jax.Array, signs: jax.Array) -> jax.Array:
    """y_o = Σ_i s_{o,i} a_i  — reference for the binary PE.

    acts:  (..., K) uint8-valued; signs: (K, N) ∈ {-1,+1}.
    Integer-exact when inputs are integers carried in int32.
    """
    return acts @ signs


def sign_accumulate_fused(acts: jax.Array, mul_prev: jax.Array,
                          signs: jax.Array) -> jax.Array:
    """Eq. 3-4: y_o = Σ_i s_{o,i} (m_i a_i) — Mul_prev fused into the PE."""
    return (acts * mul_prev) @ signs
