"""Core W1A8 quantization engine — the paper's contribution as a library.

Modules: quant (Eqs. 3-1..3-4 primitives), fixedpoint (Q-format, §4),
packing (COE/BRAM analogue), qtensor (the quantized-tensor pytree every
layer boundary speaks), w1a8 (composable layers), verify (§6.3 alignment
statistics).
"""
from repro.core import (  # noqa: F401
    fixedpoint,
    packing,
    qtensor,
    quant,
    verify,
    w1a8,
)
from repro.core.qtensor import QTensor  # noqa: F401
