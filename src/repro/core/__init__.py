"""Core W1A8 quantization engine — the paper's contribution as a library.

Modules: quant (Eqs. 3-1..3-4 primitives), fixedpoint (Q-format, §4),
packing (COE/BRAM analogue), w1a8 (composable layers), verify (§6.3
alignment statistics).
"""
from repro.core import fixedpoint, packing, quant, verify, w1a8  # noqa: F401
