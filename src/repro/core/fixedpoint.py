"""Q-format fixed-point conversion (paper §4).

The paper's deployment converts float parameters to two's-complement
fixed point: Conv1 weights Q5.11 / biases Q2.14; Conv11 weights Q1.15 /
biases Q4.12; the detection head emits signed Q*.15 (int32 / 2^15).
A Qm.n value occupies (1 sign + m integer + n fraction) bits.

All arithmetic here is integer-exact: a QFormat carries values as int32
"raw" integers; `to_float` divides by 2^frac. This mirrors the RTL datapath
so `core/verify.py` can reproduce the paper's Table-6 statistics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quant import round_half_away


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Two's-complement Qm.n: 1 sign bit, `int_bits` integer, `frac_bits` frac."""
    int_bits: int
    frac_bits: int
    signed: bool = True

    @property
    def total_bits(self) -> int:
        return (1 if self.signed else 0) + self.int_bits + self.frac_bits

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def raw_min(self) -> int:
        return -(1 << (self.int_bits + self.frac_bits)) if self.signed else 0

    @property
    def raw_max(self) -> int:
        return (1 << (self.int_bits + self.frac_bits)) - 1

    def quantize(self, x: jax.Array) -> jax.Array:
        """float → int32 raw value, saturating (matches RTL saturation)."""
        raw = round_half_away(jnp.asarray(x, jnp.float64 if x.dtype == jnp.float64
                                          else jnp.float32) * self.scale)
        return jnp.clip(raw, self.raw_min, self.raw_max).astype(jnp.int32)

    def to_float(self, raw: jax.Array) -> jax.Array:
        return raw.astype(jnp.float32) / self.scale

    def roundtrip(self, x: jax.Array) -> jax.Array:
        """Quantization the RTL would apply, back in float (max err 2^-n-1)."""
        return self.to_float(self.quantize(x))

    def __str__(self) -> str:  # "Q5.11" / "UQ0.8"
        return f"{'Q' if self.signed else 'UQ'}{self.int_bits}.{self.frac_bits}"


# Formats used by the paper (Table 3).
CONV1_W = QFormat(5, 11)          # Q5.11
CONV1_B = QFormat(2, 14)          # Q2.14
CONV11_W = QFormat(1, 15)         # Q1.15
CONV11_B = QFormat(4, 12)         # Q4.12
INPUT_Q = QFormat(0, 8, signed=False)   # RGB in Q0.8 ([0,255]/256)
HEAD_OUT = QFormat(16, 15)        # signed int32 with 15 fractional bits
SCALE_Q = QFormat(0, 16, signed=False)  # per-channel Mul/Div fixed-point scales


def fixed_mul_rshift(x, mul_raw, frac_bits: int):
    """Integer multiply + rounding right-shift: round_half_away((x*m) / 2^f).

    The RTL post-processing primitive. **numpy int64** (bit-exact golden path —
    JAX defaults to 32-bit so the exact pipeline runs in numpy; the fast
    JAX/Pallas path uses float32 scales instead and is *verified against* this).
    """
    import numpy as np
    prod = np.asarray(x, np.int64) * np.asarray(mul_raw, np.int64)
    half = np.int64(1) << (frac_bits - 1)
    # round half away from zero: floor((p + half) / 2^f) for p>=0,
    # -floor((-p + half) / 2^f) for p<0  (symmetric rounding like the RTL).
    mag = np.abs(prod)
    rounded = (mag + half) >> frac_bits
    return (np.sign(prod) * rounded).astype(np.int64)
