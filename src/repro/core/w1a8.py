"""W1A8 layers — the paper's technique as a composable JAX module.

Generalizes the paper's scheme from CNN channels to arbitrary feature axes:
  * body matmuls use 1-bit weights (sign + STE) and uint8 LSQ activations,
  * per-*input*-channel scale (``Mul_prev`` = the input quantizer's step,
    optionally channel-wise) is fused into the accumulation (Eq. 3-4),
  * per-*output*-channel scale (``Div_current`` = XNOR-style α = mean|w| per
    output channel, folded with the next quant step at deployment) + bias run
    in the epilogue,
  * first/last layers (embedding / lm_head — the Conv1/Conv11 analogue) stay
    high precision.

Three execution paths share one algebra:
  train   — fake-quant QAT (differentiable, STE + LSQ),
  infer   — packed 1-bit weights unpacked via jnp (pjit-friendly),
  kernel  — Pallas ``w1a8_matmul`` (VMEM-tiled, fused prologue/epilogue).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quant import (binarize_ste, binarize_weight,
                              lsq_fake_quant, lsq_grad_scale, quantize_act)


def init_w1a8_linear(key: jax.Array, k: int, n: int, *,
                     per_channel_step: bool = True,
                     dtype=jnp.float32) -> dict:
    """Latent params for one W1A8 linear layer (training representation)."""
    w = jax.random.normal(key, (k, n), dtype) * (1.0 / jnp.sqrt(k))
    step = jnp.full((k,) if per_channel_step else (), 0.05, dtype)
    return {"w": w, "act_step": step, "bias": jnp.zeros((n,), dtype)}


def _alpha(w: jax.Array) -> jax.Array:
    """XNOR-Net per-output-channel weight scale α_o = mean_i |w_io| (detached)."""
    return jax.lax.stop_gradient(jnp.mean(jnp.abs(w), axis=0))


def w1a8_linear_train(params: dict, x: jax.Array) -> jax.Array:
    """QAT forward: LSQ fake-quant input → ±1 (STE) matmul → α, bias epilogue."""
    gs = lsq_grad_scale(x.size // max(x.shape[-1], 1))
    xq = lsq_fake_quant(x, params["act_step"], jnp.asarray(gs, x.dtype))
    wb = binarize_ste(params["w"])
    y = xq @ wb
    return y * _alpha(params["w"]) + params["bias"]


def w1a8_linear_float_ref(params: dict, x: jax.Array) -> jax.Array:
    """Eval-mode float reference (no STE machinery) — the 'ONNX' oracle."""
    xq = quantize_act(x, params["act_step"]) * params["act_step"]
    return (xq @ binarize_weight(params["w"])) * _alpha(params["w"]) + params["bias"]


# ---------------------------------------------------------------------------
# Deployment: pack to 1-bit + scale split (the parameter-extraction step, §4)
# ---------------------------------------------------------------------------

def deploy_w1a8_linear(params: dict) -> dict:
    """Training params → deployed artifact.

    mul_prev    (K,) f32 — input quant steps (channel-wise Mul_prev)
    w_packed    (K/32, N) uint32 — sign bits, reduction-major
    div_post    (N,) f32 — α_o (output-channel scale; at graph-assembly time the
                 *next* layer's quant step is folded in, mirroring Div_current)
    bias        (N,) f32
    """
    w = params["w"]
    k = w.shape[0]
    step = jnp.broadcast_to(params["act_step"], (k,)).astype(jnp.float32)
    return {
        "w_packed": packing.pack_signs(w, axis=0),
        "mul_prev": step,
        "div_post": _alpha(w).astype(jnp.float32),
        "bias": params["bias"].astype(jnp.float32),
        "k": k,
    }


def w1a8_linear_infer(deployed: dict, a_u8: jax.Array, *,
                      compute_dtype=jnp.bfloat16) -> jax.Array:
    """Deployed inference on quantized activations (jnp path, pjit-friendly).

    a_u8: (..., K) uint8 activation codes. Returns float output
    y = ((a ⊙ mul_prev) @ sign) * div_post + bias     (Eqs. 3-2/3-4).

    The ±1 operand is unpacked from 1-bit storage *at use*: under jit the
    unpack fuses into the matmul's producer, so HBM traffic stays ~1 bit per
    weight — the TPU analogue of streaming COE ROMs.
    """
    k = deployed["k"]
    signs = packing.unpack_signs(deployed["w_packed"], k, axis=0,
                                 dtype=compute_dtype)
    am = (a_u8.astype(compute_dtype) *
          deployed["mul_prev"].astype(compute_dtype))
    y = jax.lax.dot_general(am, signs, (((am.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y * deployed["div_post"] + deployed["bias"]


def w1a8_linear_infer_int(deployed: dict, a_u8: jax.Array) -> jax.Array:
    """Uniform-scale exact-integer path: a(int32) @ sign(int32) with the
    zero-point trick (a-128 int8 + colsum correction is done in the Pallas
    kernel; here plain int32 keeps it exact on CPU)."""
    k = deployed["k"]
    signs = packing.unpack_signs(deployed["w_packed"], k, axis=0, dtype=jnp.int32)
    acc = jax.lax.dot_general(a_u8.astype(jnp.int32), signs,
                              (((a_u8.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    m = deployed["mul_prev"][0]
    return acc.astype(jnp.float32) * m * deployed["div_post"] + deployed["bias"]


def requantize(y: jax.Array, next_step: jax.Array) -> jax.Array:
    """Post-processing to the next layer's uint8 codes (Div_current role)."""
    return quantize_act(y, next_step).astype(jnp.uint8)
