"""1-bit weight packing — the TPU analogue of the paper's COE/BRAM ROM flow (§4).

The FPGA flow packs binary weight signs into COE files loaded into BRAM ROMs
in RTL address order. Here the deployed artifact is a bit-packed ``uint32``
array in HBM: bit j of word k along the packed axis holds the sign of weight
index ``32*k + j`` (1 ⇒ +1, 0 ⇒ −1, sign(0)=+1 per Eq. 3-1's RTL convention).

Packing is along the *reduction* (input-channel) axis so a Pallas kernel tile
``(bk/32, bn)`` unpacks to a ``(bk, bn)`` ±1 operand entirely in VMEM.

Storage: 1 bit/weight = 1/16 of bf16, 1/8 of int8 — this is where the paper's
"1/32 of 32-bit storage" claim lands on TPU (HBM capacity + bandwidth).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PACK = 32  # signs per uint32 word


def packed_dim(k: int) -> int:
    return (k + PACK - 1) // PACK


def pack_signs(w: jax.Array, axis: int = 0) -> jax.Array:
    """Pack sign bits of ``w`` along ``axis`` into uint32 (bit=1 ⇔ w>=0).

    w: float or ±1 array. Returns uint32 array with shape[axis] = ceil(K/32).
    K must be padded to a multiple of 32 by the caller for kernel use
    (pad with +1 signs and zero Mul_prev scales so padding contributes 0).
    """
    w = jnp.moveaxis(jnp.asarray(w), axis, 0)
    k = w.shape[0]
    kp = packed_dim(k) * PACK
    bits = (w >= 0).astype(jnp.uint32)
    if kp != k:
        pad = jnp.ones((kp - k,) + w.shape[1:], jnp.uint32)
        bits = jnp.concatenate([bits, pad], axis=0)
    bits = bits.reshape((kp // PACK, PACK) + bits.shape[1:])
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape((1, PACK) + (1,) * (bits.ndim - 2))
    words = jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)
    return jnp.moveaxis(words, 0, axis)


def unpack_signs(words: jax.Array, k: int, axis: int = 0,
                 dtype=jnp.int8) -> jax.Array:
    """Inverse of pack_signs: uint32 words → ±1 values (length k along axis)."""
    words = jnp.moveaxis(jnp.asarray(words, jnp.uint32), axis, 0)
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape((1, PACK) + (1,) * (words.ndim - 1))
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape((-1,) + words.shape[1:])[:k]
    signs = (flat.astype(jnp.int32) * 2 - 1).astype(dtype)
    return jnp.moveaxis(signs, 0, axis)


# ---------------------------------------------------------------------------
# Deployment artifact (the COE-file analogue): a directory of .npy blobs +
# a manifest, written in ROM (kernel) layout order.
# ---------------------------------------------------------------------------

def export_packed_layer(path, name: str, *, weight: np.ndarray,
                        mul_prev: np.ndarray, div_current: np.ndarray,
                        bias: np.ndarray) -> dict:
    """Write one W1A8 layer's deployment blobs; returns the manifest entry.

    weight: (K, N) float → packed (K/32, N) uint32 (reduction-major, kernel order)
    mul_prev: (K,) f32; div_current/bias: (N,) f32.
    """
    import os
    os.makedirs(path, exist_ok=True)
    packed = np.asarray(pack_signs(jnp.asarray(weight), axis=0))
    blobs = {"w_packed": packed.astype(np.uint32),
             "mul_prev": np.asarray(mul_prev, np.float32),
             "div_current": np.asarray(div_current, np.float32),
             "bias": np.asarray(bias, np.float32)}
    entry = {"name": name, "k": int(weight.shape[0]), "n": int(weight.shape[1])}
    for key, arr in blobs.items():
        fn = f"{name}.{key}.npy"
        np.save(os.path.join(path, fn), arr)
        entry[key] = {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    return entry


def load_packed_layer(path, entry: dict) -> dict:
    import os
    out = {}
    for key in ("w_packed", "mul_prev", "div_current", "bias"):
        out[key] = np.load(os.path.join(path, entry[key]["file"]))
    return out
