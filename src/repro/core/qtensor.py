"""QTensor — the one quantized-tensor abstraction of the W1A8 dataflow.

The paper's datapath never carries raw floats between stages: every wire is
codes-plus-scale (uint8 activation codes with their LSQ step, 1-bit weight
sign words with the α magnitude, int8 gradient codes with a shared abs-max
scale). Before this module each boundary re-invented that pair ad hoc —
``(codes, cur_steps)`` threading through ``models/yolo.py``, bare int8 codes
inside ``dist/collectives.py``, f32 arrays on the pipeline permute wire.
QTensor names the pair once and rides pytrees, so the same object crosses
kernel boundaries, ``ppermute`` wires and jit boundaries unchanged.

Payload conventions (``qtype``):

  ``u8``   uint8 activation codes, value = data · scale, scale per-tensor or
           per-channel along ``axis`` (the LSQ step; ``core.quant``).
  ``s8``   symmetric int8 codes in [−127, 127], value = data · scale with a
           per-tensor scale = abs-max/127 (the dist wire format).
  ``b1``   1-bit sign words (uint32, 32 signs/word along the reduction axis;
           ``core.packing``), value = unpack(data) · scale (α). ``kdim``
           holds the unpadded logical length of the packed axis.
  ``f32``  escape hatch: unquantized payload, scale ≡ 1.

``data`` and ``scale`` are pytree children (they trace/shard/permute);
``qtype``, ``axis`` and ``kdim`` are static aux data, so a QTensor's wire
format is part of its pytree structure — two QTensors with different
formats never silently unify under ``jax.lax.cond``/``jnp.where``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quant import ACT_QMAX, round_half_away

S8_QMAX = 127  # symmetric int8 code range [-127, 127] (dist wire format)

_QTYPES = ("u8", "s8", "b1", "f32")


@dataclasses.dataclass(frozen=True)
class QTensor:
    """dtype-tagged quantized payload + scale, registered as a pytree."""

    data: jax.Array                 # codes / sign words / raw payload
    scale: jax.Array                # per-tensor scalar or per-channel vector
    qtype: str = "u8"               # one of _QTYPES (static)
    axis: Optional[int] = None      # channel axis of a per-channel scale
    kdim: Optional[int] = None      # b1: unpadded length of the packed axis

    def __post_init__(self):
        if self.qtype not in _QTYPES:
            raise ValueError(f"unknown qtype {self.qtype!r}")

    # -- constructors --------------------------------------------------------
    @classmethod
    def quantize_u8(cls, x: jax.Array, step: jax.Array,
                    axis: Optional[int] = None) -> "QTensor":
        """clip(round(x/s), 0, 255) uint8 codes (Eq. 3-3 discipline)."""
        codes = jnp.clip(round_half_away(x / step), 0,
                         ACT_QMAX).astype(jnp.uint8)
        return cls(codes, jnp.asarray(step, jnp.float32), "u8", axis=axis)

    @classmethod
    def from_codes(cls, codes: jax.Array, step: jax.Array,
                   axis: Optional[int] = None) -> "QTensor":
        """Wrap already-quantized uint8 codes with their step."""
        return cls(codes, jnp.asarray(step, jnp.float32), "u8", axis=axis)

    @classmethod
    def quantize_s8(cls, x: jax.Array,
                    scale: Optional[jax.Array] = None) -> "QTensor":
        """Symmetric int8 with per-tensor scale = abs-max/127 (dist wire).

        An explicit ``scale`` (e.g. a pmax-shared one) overrides the local
        abs-max so codes from different shards stay summable.
        """
        x = jnp.asarray(x)
        if scale is None:
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / S8_QMAX
        codes = jnp.clip(round_half_away(x / scale), -S8_QMAX,
                         S8_QMAX).astype(jnp.int8)
        return cls(codes, jnp.asarray(scale, jnp.float32), "s8")

    @classmethod
    def pack_b1(cls, w: jax.Array, alpha: Optional[jax.Array] = None,
                axis: int = 0) -> "QTensor":
        """Pack sign bits along the reduction ``axis`` (Eq. 3-1 + §4 COE)."""
        if alpha is None:
            alpha = jnp.mean(jnp.abs(w), axis=axis)
        return cls(packing.pack_signs(w, axis=axis),
                   jnp.asarray(alpha, jnp.float32), "b1", axis=axis,
                   kdim=int(w.shape[axis]))

    @classmethod
    def quantize_b1(cls, x: jax.Array, axis: int = -1,
                    per_slice: bool = False) -> "QTensor":
        """Sign-binarize ``x`` to packed words along ``axis`` + α = mean|x|.

        The b1 *activation* wire format (``dist.collectives``): value ≈
        sign(x)·α — 1 bit per element plus one 4-byte scale, the densest
        wire the W1A8 dataflow owns, for sign-dominated boundaries where
        magnitude is already saturated. α is per-tensor by default;
        ``per_slice=True`` computes one α per slice along ``axis``
        (kept as a broadcastable keepdims vector). Either way α is
        clamped to 1e-20 exactly like the s8 wire scale (`quantize_s8`):
        an all-zero tensor — or, per slice, an all-zero row — would
        otherwise carry α = 0, which NaN-poisons any consumer that
        divides by the scale; clamped, the round-trip stays finite with
        |x̂| ≤ 1e-20.
        """
        x = jnp.asarray(x)
        ax = axis if axis >= 0 else x.ndim + axis
        if per_slice:
            alpha = jnp.mean(jnp.abs(x), axis=ax, keepdims=True)
        else:
            alpha = jnp.mean(jnp.abs(x))
        alpha = jnp.maximum(alpha.astype(jnp.float32), 1e-20)
        return cls(packing.pack_signs(x, axis=ax), alpha, "b1",
                   axis=ax, kdim=int(x.shape[ax]))

    @classmethod
    def from_f32(cls, x: jax.Array) -> "QTensor":
        return cls(jnp.asarray(x), jnp.ones((), jnp.float32), "f32")

    # -- views ---------------------------------------------------------------
    def dequantize(self) -> jax.Array:
        """Back to f32 values (codes · scale; b1 unpacks to ±1 · α)."""
        if self.qtype == "b1":
            signs = packing.unpack_signs(self.data, self.kdim,
                                         axis=self.axis, dtype=jnp.float32)
            return signs * self.scale
        return self.data.astype(jnp.float32) * self.scale

    @property
    def per_tensor(self) -> bool:
        return jnp.ndim(self.scale) == 0 or jnp.size(self.scale) == 1

    def scale_scalar(self) -> jax.Array:
        """The per-tensor scale (contract of the popcount/exact paths)."""
        return jnp.reshape(self.scale, (-1,))[0]

    def wire_bytes(self) -> int:
        """Payload + scale bytes this tensor costs on a wire (vs f32)."""
        return int(self.data.size * self.data.dtype.itemsize
                   + jnp.size(self.scale) * 4)

    def __repr__(self) -> str:  # concise — data/scale may be tracers
        return (f"QTensor(qtype={self.qtype!r}, shape={self.data.shape}, "
                f"scale_shape={jnp.shape(self.scale)}, axis={self.axis})")


def _flatten(qt: QTensor):
    return (qt.data, qt.scale), (qt.qtype, qt.axis, qt.kdim)


def _unflatten(aux, children) -> QTensor:
    qtype, axis, kdim = aux
    return QTensor(children[0], children[1], qtype, axis=axis, kdim=kdim)


jax.tree_util.register_pytree_node(QTensor, _flatten, _unflatten)
