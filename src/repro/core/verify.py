"""Layer-wise numerical alignment — the paper's §6.3 verification methodology.

The paper validates the RTL datapath against ONNX Runtime node-by-node with
max-abs error, mean-abs error, correlation, and %-of-outputs-within-1-LSB
(Table 6). Here the roles are:
    "RTL"  → the deployed integer-exact pipeline / Pallas kernel path
    "ONNX" → the float reference model (ref.py oracles / float yolo)
and the same four statistics are produced per comparison point.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class AlignmentReport:
    name: str
    max_abs: float
    mean_abs: float
    corr: float
    within_1lsb: float  # fraction in [0,1]; LSB defined by `lsb` arg
    n: int

    def row(self) -> str:
        return (f"{self.name:<28s} max_abs={self.max_abs:.6g} "
                f"mean_abs={self.mean_abs:.6g} corr={self.corr:.6f} "
                f"within_1LSB={100.0 * self.within_1lsb:.4f}%")


def compare(name: str, test: np.ndarray, ref: np.ndarray,
            lsb: float = 1.0) -> AlignmentReport:
    """Table-6 statistics for one verification target."""
    t = np.asarray(test, np.float64).ravel()
    r = np.asarray(ref, np.float64).ravel()
    assert t.shape == r.shape, (t.shape, r.shape)
    diff = np.abs(t - r)
    denom = float(np.std(t) * np.std(r))
    corr = float(np.mean((t - t.mean()) * (r - r.mean())) / denom) if denom > 0 else 1.0
    return AlignmentReport(
        name=name,
        max_abs=float(diff.max()) if t.size else 0.0,
        mean_abs=float(diff.mean()) if t.size else 0.0,
        corr=corr,
        within_1lsb=float(np.mean(diff <= lsb + 1e-12)),
        n=t.size,
    )


def print_table(reports) -> str:
    lines = ["verification target            statistics",
             "-" * 78]
    lines += [r.row() for r in reports]
    out = "\n".join(lines)
    print(out)
    return out
