"""Transformer building blocks with first-class W1A8 quantization.

Every projection can run in three modes (the paper's scheme generalized from
CNN channels to features — see DESIGN.md §3):
  "float"       — plain bf16/f32 matmul (the fp baseline the paper compares to)
  "w1a8_train"  — QAT: LSQ fake-quant activations + sign-STE weights
  "w1a8_eval"   — deployment algebra on fake-quant params (eval oracle)
Packed-bit serving lives in repro/serve (weights pre-packed offline).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import (binarize_ste, binarize_weight, lsq_fake_quant,
                              lsq_grad_scale, quantize_act)

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads
    # attention flavor
    rope_theta: float = 1e4
    rope_fraction: float = 1.0     # chatglm3: 0.5 (2D RoPE)
    qkv_bias: bool = False         # qwen2.5
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    sliding_window: int = 0        # mixtral: 4096; gemma2 local layers: 4096
    local_global: bool = False     # gemma2: alternate SWA / global layers
    post_norms: bool = False       # gemma2: post-attn/post-ffn RMSNorm
    # MoE
    num_experts: int = 0
    top_k: int = 0
    shared_experts: int = 0        # kimi-k2: 1
    moe_every: int = 1             # jamba: 2 (MoE on every other layer)
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_kind: str = "mamba2"       # mamba2 (SSD) | mamba1 (selective scan)
    attn_every: int = 0            # jamba: 8 (1 attention per 8 layers)
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # perf: blockwise (flash) attention — 0 = off, else KV/Q block size;
    # kills the S² score materialization for long prefill/train (§Perf)
    flash_block: int = 0
    # perf: pad query heads to a TP-divisible count (qwen 40→48 for TP16);
    # extra heads are real params, ~heads_pad/heads extra attn compute, but
    # remove per-layer activation all-gathers (§Perf cell A)
    pad_heads_to: int = 0
    # perf: keep the flat head dim in attention einsums and expand KV heads
    # (repeat) so XLA shards activations on H even when kv% tp != 0 (§Perf)
    flat_head_attn: bool = False
    # enc-dec / modality stub
    encoder_layers: int = 0
    frontend: str = "none"         # none | audio | vision
    prefix_len: int = 0            # vision: 256 patch embeddings
    tie_embeddings: bool = True
    norm_kind: str = "rms"         # rms | layer
    act_fn: str = "silu"           # silu | gelu
    gated_mlp: bool = True
    # the paper's technique
    w1a8_body: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def heads_eff(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def period(self) -> int:
        """Repeating layer-pattern length (scan unit)."""
        p = 1
        if self.local_global:
            p = 2
        if self.attn_every:
            p = max(p, self.attn_every)
        if self.num_experts and self.moe_every > 1:
            p = max(p, self.moe_every)
        return p

    def mixer_kind(self, i: int) -> str:
        if self.family in ("ssm",):
            return "mamba"
        if self.attn_every:                      # hybrid: 1 attn per period
            return "attn" if i % self.attn_every == self.attn_every // 2 \
                else "mamba"
        if self.local_global:                    # gemma2: local, global, ...
            return "attn_local" if i % 2 == 0 else "attn_global"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        if self.family == "ssm":
            return "none"
        if self.num_experts and i % self.moe_every == self.moe_every - 1:
            return "moe"
        return "dense"


# ---------------------------------------------------------------------------
# Linear with W1A8 switch
# ---------------------------------------------------------------------------

def init_linear(key, k: int, n: int, *, w1a8: bool, bias: bool = False,
                dtype=jnp.float32, scale: float = 1.0) -> dict:
    p = {"w": jax.random.normal(key, (k, n), dtype) * (scale / jnp.sqrt(k))}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    if w1a8:
        p["act_step"] = jnp.full((), 0.05, dtype)   # scalar LSQ step (body)
    return p


def linear(p: dict, x: jax.Array, mode: str = "float") -> jax.Array:
    """Apply a (possibly W1A8) projection; mode selects the datapath."""
    if "w_packed" in p:
        # deployed 1-bit weights (serve.packed): unpack at use — under jit
        # the unpack fuses into the matmul producer, so HBM weight traffic
        # is ~1 bit/weight (16× less than bf16); decode is weight-BW bound.
        from repro.core import packing
        signs = packing.unpack_signs(p["w_packed"], x.shape[-1], axis=0,
                                     dtype=x.dtype)
        step = p["act_step"].astype(x.dtype)
        xq = quantize_act(x, step) * step
        y = (xq @ signs) * p["alpha"].astype(x.dtype)
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    w = p["w"]
    if "act_step" in p and mode != "float":
        if mode == "w1a8_train":
            gs = lsq_grad_scale(x.size // max(x.shape[-1], 1))
            xq = lsq_fake_quant(x, p["act_step"], jnp.asarray(gs, x.dtype))
            wb = binarize_ste(w)
        else:  # w1a8_eval
            xq = quantize_act(x, p["act_step"]) * p["act_step"]
            wb = binarize_weight(w)
        alpha = jax.lax.stop_gradient(jnp.mean(jnp.abs(w), axis=0))
        y = (xq @ wb.astype(xq.dtype)) * alpha.astype(xq.dtype)
    else:
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str = "rms", dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p: dict, x: jax.Array, kind: str = "rms",
         eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + partial/2D fraction)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, *, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S). chatglm3 rotates only the
    first half of head_dim (fraction=0.5, '2D RoPE')."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


# ---------------------------------------------------------------------------
# Attention (GQA, SWA, softcap, cross) — pure jnp, shard-friendly
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    he = cfg.heads_eff
    w1a8 = cfg.w1a8_body
    return {
        "wq": init_linear(ks[0], d, he * hd, w1a8=w1a8,
                          bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, cfg.num_kv_heads * hd, w1a8=w1a8,
                          bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, cfg.num_kv_heads * hd, w1a8=w1a8,
                          bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], he * hd, d, w1a8=w1a8,
                          dtype=dtype),
    }


def _attn_weights(q, k, *, causal: bool, window: int, softcap: float,
                  q_pos, k_pos):
    """q (B,S,H,hd), k (B,T,KV,hd) → probs (B,H,S,T) with GQA broadcast."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if q_pos is not None and (causal or window > 0):
        qp = q_pos[:, :, None]
        kp = k_pos[:, None, :]
        valid = jnp.ones((b, s, t), bool)
        if causal:
            valid &= kp <= qp
        if window > 0:
            valid &= kp > qp - window
        logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return probs.astype(q.dtype), g


def _blockwise_attention(q, k, v, *, causal: bool, window: int,
                         softcap: float, q_pos, k_pos, block: int):
    """Flash-attention pattern in pure JAX: double-chunked online softmax.

    Never materializes the (S, T) score matrix — peak extra memory is
    O(block²) per head. q (B,S,H,hd); k/v (B,T,KV,hd). Positions drive the
    causal/window mask so ragged batches work unchanged.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(block, s)
    bk = min(block, t)
    nq, nk = -(-s // bq), -(-t // bk)
    pad_q, pad_k = nq * bq - s, nk * bk - t
    qp = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    kp = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=2 ** 30)
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qps = qp.reshape(b, nq, bq).transpose(1, 0, 2)
    ks = k.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    kps = kp.reshape(b, nk, bk).transpose(1, 0, 2)

    def q_block(args):
        qb, qpb = args                                  # (B,bq,KV,G,hd)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpb = inp                           # (B,bk,KV,hd)
            logits = jnp.einsum("bqkgd,btkd->bkgqt", qb, kb) \
                .astype(jnp.float32) * scale
            if softcap > 0:
                logits = softcap * jnp.tanh(logits / softcap)
            valid = jnp.ones((b, bq, bk), bool)
            if causal:
                valid &= kpb[:, None, :] <= qpb[:, :, None]
            if window > 0:
                valid &= kpb[:, None, :] > qpb[:, :, None] - window
            logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, -1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, -1)
            # f32 accumulator regardless of activation dtype (carry-stable)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, kv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B,bq,KV,G,hd)

    outs = jax.lax.map(q_block, (qs, qps))              # (nq,B,bq,KV,G,hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, hd)
    return out[:, :s]


def attention(p: dict, cfg: ModelConfig, x: jax.Array, *,
              mode: str, causal: bool = True, window: int = 0,
              positions: Optional[jax.Array] = None,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Self- or cross-attention (kv_x given ⇒ cross, no RoPE on kv source)."""
    b, s, d = x.shape
    hd = cfg.hd
    src = kv_x if kv_x is not None else x
    t = src.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if kv_positions is None:
        kv_positions = positions if kv_x is None else \
            jnp.broadcast_to(jnp.arange(t), (b, t))
    q = linear(p["wq"], x, mode).reshape(b, s, cfg.heads_eff, hd)
    k = linear(p["wk"], src, mode).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(p["wv"], src, mode).reshape(b, t, cfg.num_kv_heads, hd)
    if kv_x is None:                              # RoPE only for self-attn
        q = rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        k = rope(k, kv_positions, theta=cfg.rope_theta,
                 fraction=cfg.rope_fraction)
    if cfg.flat_head_attn:
        # MHA-ify: expand KV to the flat head dim so activations shard on H
        g = cfg.heads_eff // cfg.num_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if cfg.flash_block > 0 and s > cfg.flash_block and kv_x is None:
        out = _blockwise_attention(q, k, v, causal=causal, window=window,
                                   softcap=cfg.attn_softcap,
                                   q_pos=positions, k_pos=kv_positions,
                                   block=cfg.flash_block)
        return linear(p["wo"], out.reshape(b, s, -1), mode)
    probs, g = _attn_weights(q, k, causal=causal and kv_x is None,
                             window=window, softcap=cfg.attn_softcap,
                             q_pos=positions, k_pos=kv_positions)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v).reshape(b, s, -1)
    return linear(p["wo"], out, mode)


# ---------------------------------------------------------------------------
# MLP (gated / plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    w1a8 = cfg.w1a8_body
    p = {"up": init_linear(ks[0], d, f, w1a8=w1a8, dtype=dtype),
         "down": init_linear(ks[1], f, d, w1a8=w1a8, dtype=dtype)}
    if cfg.gated_mlp:
        p["gate"] = init_linear(ks[2], d, f, w1a8=w1a8, dtype=dtype)
    return p


def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def mlp(p: dict, cfg: ModelConfig, x: jax.Array, mode: str) -> jax.Array:
    up = linear(p["up"], x, mode)
    if "gate" in p:
        up = up * _act(cfg.act_fn)(linear(p["gate"], x, mode))
    else:
        up = _act(cfg.act_fn)(up)
    return linear(p["down"], up, mode)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    emb = jax.random.normal(key, (cfg.vocab_size, cfg.d_model), dtype) * 0.02
    p = {"emb": emb}
    if not cfg.tie_embeddings:
        key2 = jax.random.fold_in(key, 1)
        p["head"] = jax.random.normal(
            key2, (cfg.d_model, cfg.vocab_size), dtype) * 0.02
    return p


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0)


def unembed(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    logits = x @ (p["head"] if "head" in p else p["emb"].T.astype(x.dtype))
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits
