"""Top-k dropping MoE with expert-parallel all-to-all dispatch.

Production path (DeepSpeed-MoE/Switch style, TPU-native):
  experts sharded over the `ep` mesh axis, expert-FFN hidden over `tp`;
  tokens are sorted by destination expert, packed into a static
  (ep, E_local, C, D) buffer, exchanged with `lax.all_to_all`, processed as
  grouped GEMMs, exchanged back, and combined with router gates. Capacity
  C = ceil(T_local · k / E · cf) bounds the buffers (dropped tokens pass
  through with gate 0 — standard dropping semantics).

Single-device path: identical math with the a2a as identity (ep=1), used by
smoke tests; the shard_map wiring lives in repro/dist/sharding.py.

W1A8: expert weights are (E, K, N) stacks; in QAT mode they binarize with
sign-STE exactly like dense layers (per-expert α) — for kimi-k2 this is the
headline 1-bit-expert capacity win (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (jax.lax.axis_size shim on older jax)
from repro.core.quant import binarize_ste, lsq_fake_quant, lsq_grad_scale
from repro.models.layers import ModelConfig, _act


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = 1.0 / math.sqrt(d)
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * s,
        "up": jax.random.normal(ks[1], (e, d, f), dtype) * s,
        "gate": jax.random.normal(ks[2], (e, d, f), dtype) * s,
        "down": jax.random.normal(ks[3], (e, f, d), dtype) / math.sqrt(f),
    }
    if cfg.w1a8_body:
        p["act_step"] = jnp.full((), 0.05, dtype)
    if cfg.shared_experts:
        fs = f * cfg.shared_experts
        p["shared_up"] = jax.random.normal(ks[4], (d, fs), dtype) * s
        p["shared_gate"] = jax.random.normal(
            jax.random.fold_in(ks[4], 1), (d, fs), dtype) * s
        p["shared_down"] = jax.random.normal(
            jax.random.fold_in(ks[4], 2), (fs, d), dtype) / math.sqrt(fs)
    return p


def _expert_mm(p: dict, name: str, x: jax.Array, mode: str,
               mean_axis: Optional[str] = None) -> jax.Array:
    """Grouped GEMM (E, T, K) @ (E, K, N), W1A8 QAT / packed-deploy aware.

    mean_axis: mesh axis the contraction (K) dim is TP-sliced over — the
    XNOR α = mean_K|w| must then be pmean'd to equal the global mean
    (down-proj under TP-in-expert).
    """
    act_step = p.get("act_step")
    if name + "_packed" in p:                     # deployed 1-bit experts
        from repro.core.packing import unpack_signs
        from repro.core.quant import quantize_act
        signs = unpack_signs(p[name + "_packed"], x.shape[-1], axis=-2,
                             dtype=x.dtype)
        step = act_step.astype(x.dtype)
        xq = quantize_act(x, step) * step
        return jnp.einsum("etk,ekn->etn", xq, signs) \
            * p[name + "_alpha"].astype(x.dtype)
    w = p[name]
    if act_step is not None and mode != "float":
        gs = lsq_grad_scale(max(x.size // max(x.shape[-1], 1), 1))
        x = lsq_fake_quant(x, act_step, jnp.asarray(gs, x.dtype))
        wb = binarize_ste(w)
        alpha = jnp.mean(jnp.abs(w), axis=1, keepdims=True)
        if mean_axis:
            alpha = jax.lax.pmean(alpha, mean_axis)
        alpha = jax.lax.stop_gradient(alpha)
        return jnp.einsum("etk,ekn->etn", x, wb.astype(x.dtype)) \
            * alpha.astype(x.dtype)
    return jnp.einsum("etk,ekn->etn", x, w.astype(x.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _a2a_u8(x_and_step, axis: str):
    """uint8-wire all_to_all of activation codes (W1A8 theme → collectives).

    Forward: quantize to uint8 codes against `step`, exchange 1-byte payload
    (4× less ICI traffic than f32, 2× less than bf16), dequantize.
    Backward: plain a2a of the cotangent (a2a is a permutation) with STE
    through the quantizer.
    """
    x, step = x_and_step
    from repro.core.quant import quantize_act
    codes = quantize_act(x, step).astype(jnp.uint8)
    codes = jax.lax.all_to_all(codes, axis, split_axis=0, concat_axis=0)
    return codes.astype(x.dtype) * step


def _a2a_u8_fwd(x_and_step, axis):
    return _a2a_u8(x_and_step, axis), None


def _a2a_u8_bwd(axis, _, ct):
    return ((jax.lax.all_to_all(ct, axis, split_axis=0, concat_axis=0),
             jnp.zeros((), ct.dtype)),)


_a2a_u8.defvjp(_a2a_u8_fwd, _a2a_u8_bwd)


@dataclasses.dataclass(frozen=True)
class MoEDispatch:
    """Static dispatch plan for one MoE call."""
    num_experts: int
    top_k: int
    capacity: int       # per-expert, per source shard
    ep: int             # expert-parallel degree (1 = single shard)


def plan_dispatch(cfg: ModelConfig, tokens_local: int, ep: int) -> MoEDispatch:
    """NOTE: capacity dropping means outputs depend on batch composition —
    a 12-token prefill and the same 12 tokens inside a longer batch may
    drop differently (standard Switch/dropping semantics). For strict
    decode≡forward determinism set capacity_factor ≥ num_experts
    (mathematical no-drop bound: cap ≥ T·k), as the reduced test configs do.
    """
    cap = max(1, math.ceil(tokens_local * cfg.top_k * cfg.capacity_factor
                           / cfg.num_experts))
    cap = min(cap, tokens_local * cfg.top_k)      # no point beyond T·k
    # pad capacity to an MXU-friendly multiple where it matters
    cap = max(8, -(-cap // 8) * 8)
    return MoEDispatch(cfg.num_experts, cfg.top_k, cap, ep)


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array, *, mode: str,
            ep_axis: Optional[str] = None,
            tp_axis: Optional[str] = None,
            shared_tp: Optional[str] = None,
            a2a_quant: bool = False) -> jax.Array:
    """x: (T_local, D) tokens on this shard → (T_local, D).

    When `ep_axis` is set (inside shard_map), experts are sharded over that
    axis and tokens are exchanged with all_to_all; otherwise all experts are
    local (ep=1) and the same code runs without collectives. When `tp_axis`
    is set, expert FFN hidden dims are sharded over it and the down-proj is
    psum-reduced (TP within expert).
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    ep = jax.lax.axis_size(ep_axis) if ep_axis else 1
    disp = plan_dispatch(cfg, t, ep)
    cap, e_local = disp.capacity, e // ep

    # --- routing -----------------------------------------------------------
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    gates, idx = jax.lax.top_k(logits, k)                    # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    # --- pack: order assignments by expert, keep first `cap` per expert ----
    flat_e = idx.reshape(-1)                                  # (T·k,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    # rank of each assignment within its expert
    pos_in_e = jnp.arange(t * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                    side="left")
    keep = pos_in_e < cap
    src_tok = order // k                                      # token index
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, pos_in_e].add(
        jnp.where(keep[:, None], x[src_tok], 0))

    # --- all_to_all to expert shards ---------------------------------------
    if ep_axis:
        buf = buf.reshape(ep, e_local, cap, d)
        if a2a_quant and "act_step" in p:
            # W1A8 dispatch: ship uint8 codes (the experts re-quantize with
            # the same step anyway, so this is ~lossless — §Perf cell B)
            buf = _a2a_u8((buf, p["act_step"]), ep_axis)
        else:
            buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                     concat_axis=0, tiled=False)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_local, ep * cap, d)
    else:
        buf = buf.reshape(e_local, cap, d)

    # --- expert computation (grouped GEMM, W1A8-aware, TP over tp_axis) ----
    up = _expert_mm(p, "up", buf, mode)
    gate = _expert_mm(p, "gate", buf, mode)
    h = up * _act(cfg.act_fn)(gate)
    out = _expert_mm(p, "down", h, mode, mean_axis=tp_axis)  # (e_l, ep·cap, d)
    if tp_axis:
        out = jax.lax.psum(out, tp_axis)                      # TP reduce

    # --- return to source shards & unpack ----------------------------------
    if ep_axis:
        out = out.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3)
        if a2a_quant and out.dtype == jnp.float32:
            out = out.astype(jnp.bfloat16)        # halve the return wire
        out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        out = out.reshape(e, cap, d).astype(x.dtype)
    else:
        out = out.reshape(e, cap, d)

    fetched = jnp.where(keep[:, None], out[sorted_e, pos_in_e], 0)
    contrib = jnp.zeros((t, k, d), x.dtype).at[src_tok, order % k].add(fetched)
    y = jnp.sum(contrib * gates[..., None], axis=1)

    # --- shared experts (kimi-k2): always-on dense path --------------------
    if "shared_up" in p:
        h = (x @ p["shared_up"].astype(x.dtype)) \
            * _act(cfg.act_fn)(x @ p["shared_gate"].astype(x.dtype))
        sh = h @ p["shared_down"].astype(x.dtype)
        y = y + (jax.lax.psum(sh, shared_tp) if shared_tp else sh)

    # auxiliary load-balance loss (Switch): stored via jax.debug? — returned
    # by caller-side hook; kept here as an attribute-free pure function.
    return y


def load_balance_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style auxiliary loss: E · Σ_e f_e · p_e  (train-time hook)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(logits, cfg.top_k)
    f = jnp.mean(jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32),
                 axis=(0, 1))
    return cfg.num_experts * jnp.sum(f * jnp.mean(probs, 0)) * 1e-2
