"""Detection-head decode + NMS (paper §6.2 post-processing, the "PS side").

The head emits (B, 10, 10, 75) raw values = 3 anchors × (tx, ty, tw, th,
obj, 20 cls) per cell, y/x/channel order. Decode follows YOLOv3:
  bx = (σ(tx) + cx)/G, by = (σ(ty) + cy)/G, bw = pw·e^tw, bh = ph·e^th,
confidence = σ(obj)·max σ(cls). NMS is class-wise greedy IoU suppression,
implemented with a fixed-iteration lax.fori_loop (jit-safe, static shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.yolo import NUM_ANCHORS, NUM_CLASSES

# Anchor priors (fraction of image size), 3 anchors for the single 10×10 head.
ANCHORS = jnp.asarray([[0.12, 0.18], [0.32, 0.42], [0.72, 0.78]], jnp.float32)


def decode_head(raw: jax.Array) -> dict:
    """raw (B, G, G, 75) → boxes (B, G·G·A, 4) cxcywh in [0,1], scores, cls.

    G is read off the raw head (10 for the deployment 320×320 input; a
    resolution bucket of side S decodes a G = S/32 grid) — box coordinates
    stay image-relative fractions, so every bucket shares one decode."""
    b, grid = raw.shape[0], raw.shape[1]
    r = raw.reshape(b, grid, grid, NUM_ANCHORS, 5 + NUM_CLASSES)
    cy, cx = jnp.meshgrid(jnp.arange(grid, dtype=jnp.float32),
                          jnp.arange(grid, dtype=jnp.float32), indexing="ij")
    bx = (jax.nn.sigmoid(r[..., 0]) + cx[None, :, :, None]) / grid
    by = (jax.nn.sigmoid(r[..., 1]) + cy[None, :, :, None]) / grid
    bw = ANCHORS[None, None, None, :, 0] * jnp.exp(jnp.clip(r[..., 2], -8, 8))
    bh = ANCHORS[None, None, None, :, 1] * jnp.exp(jnp.clip(r[..., 3], -8, 8))
    obj = jax.nn.sigmoid(r[..., 4])
    cls_prob = jax.nn.sigmoid(r[..., 5:])
    boxes = jnp.stack([bx, by, bw, bh], axis=-1).reshape(b, -1, 4)
    scores = (obj[..., None] * cls_prob).reshape(b, -1, NUM_CLASSES)
    return {"boxes": boxes, "scores": scores}


def iou_cxcywh(a: jax.Array, b: jax.Array) -> jax.Array:
    """IoU between (..., 4) and (..., 4) cxcywh boxes."""
    ax1, ay1 = a[..., 0] - a[..., 2] / 2, a[..., 1] - a[..., 3] / 2
    ax2, ay2 = a[..., 0] + a[..., 2] / 2, a[..., 1] + a[..., 3] / 2
    bx1, by1 = b[..., 0] - b[..., 2] / 2, b[..., 1] - b[..., 3] / 2
    bx2, by2 = b[..., 0] + b[..., 2] / 2, b[..., 1] + b[..., 3] / 2
    iw = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0)
    ih = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0)
    inter = iw * ih
    union = (ax2 - ax1) * (ay2 - ay1) + (bx2 - bx1) * (by2 - by1) - inter
    return inter / jnp.maximum(union, 1e-9)


def nms(boxes: jax.Array, scores: jax.Array, *, iou_thresh: float = 0.45,
        score_thresh: float = 0.25, max_out: int = 50):
    """Greedy class-agnostic-per-class NMS, static shapes (jit-safe).

    boxes (N, 4), scores (N, C) → (max_out, 4), (max_out,), (max_out,) int32
    class ids; empty slots have score 0 and class -1.
    """
    cls_id = jnp.argmax(scores, axis=-1)
    score = jnp.max(scores, axis=-1)
    score = jnp.where(score >= score_thresh, score, 0.0)

    def body(i, state):
        sc, out_b, out_s, out_c = state
        j = jnp.argmax(sc)
        best = sc[j]
        out_b = out_b.at[i].set(boxes[j])
        out_s = out_s.at[i].set(best)
        out_c = out_c.at[i].set(jnp.where(best > 0, cls_id[j], -1))
        ious = iou_cxcywh(boxes[j][None, :], boxes)
        same_cls = cls_id == cls_id[j]
        suppress = (ious > iou_thresh) & same_cls
        sc = jnp.where(suppress, 0.0, sc).at[j].set(0.0)
        return sc, out_b, out_s, out_c

    init = (score, jnp.zeros((max_out, 4)), jnp.zeros((max_out,)),
            jnp.full((max_out,), -1, jnp.int32))
    _, ob, os_, oc = jax.lax.fori_loop(0, max_out, body, init)
    os_ = jnp.where(os_ > 0, os_, 0.0)
    return ob, os_, oc


import functools


@functools.partial(jax.jit,
                   static_argnames=("iou_thresh", "score_thresh", "max_out"))
def postprocess(raw: jax.Array, *, iou_thresh: float = 0.45,
                score_thresh: float = 0.25, max_out: int = 50):
    """Full post-processing for a batch of raw heads."""
    dec = decode_head(raw)
    return jax.vmap(lambda b, s: nms(b, s, iou_thresh=iou_thresh,
                                     score_thresh=score_thresh,
                                     max_out=max_out))(dec["boxes"],
                                                       dec["scores"])


def compact_detections(boxes: jax.Array, scores: jax.Array,
                       classes: jax.Array):
    """Static-shape NMS output for ONE image → the device-side emission wire.

    (max_out, 4) f32 boxes, (max_out,) f32 scores, (max_out,) int32 class
    ids → (fp16 boxes, fp16 scores, int8 classes, int32 valid-count).
    Greedy NMS emits kept boxes in descending-score order, so the positive
    slots are a prefix and one int32 prefix length stands in for a mask.
    9 bytes/slot instead of 28 — and a backend shipping this instead of the
    raw head drops the 4·G·G·75-byte tensor from every device→host sync.
    fp16 is lossless for the set structure (the NMS ran in f32; only the
    emitted values round: boxes in [0,1] to ~2⁻¹¹, scores to ~1e-3)."""
    valid = jnp.sum((scores > 0).astype(jnp.int32))
    return (boxes.astype(jnp.float16), scores.astype(jnp.float16),
            classes.astype(jnp.int8), valid)


def detections_to_list(boxes, scores, classes) -> list:
    """Static-shape NMS output for ONE image → host-side list of dicts
    (empty slots dropped) — the wire form of a detection ServeResult."""
    import numpy as np
    boxes, scores, classes = (np.asarray(boxes), np.asarray(scores),
                              np.asarray(classes))
    keep = scores > 0
    return [{"box_cxcywh": boxes[i].tolist(), "score": float(scores[i]),
             "class_id": int(classes[i])} for i in np.flatnonzero(keep)]
