"""Generic LM assembly: dense / MoE / SSM / hybrid / enc-dec, scan-over-stages.

The layer pattern repeats with period ``cfg.period`` (1 for uniform stacks,
2 for gemma2 local/global + MoE-every-other, 8 for jamba's 1-attn:7-mamba).
Parameters are stacked over stages (leading dim L/period) and the stack is
consumed by ``lax.scan`` — HLO holds one period's body regardless of depth,
keeping multi-hundred-layer configs compilable in the dry-run.

W1A8 (the paper's technique): every body projection runs through
``layers.linear`` in the requested mode; embedding and LM head stay
full-precision (the Conv1/Conv11 rule — cf. BitNet-style W1A8 transformers).

MoE layers execute inside ``shard_map`` (EP all-to-all over the data axis,
TP psum over the model axis) when a ShardCtx is provided; without one the
identical math runs single-device (smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401  (jax.shard_map shim on older jax)
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import (ModelConfig, attention, embed,
                                 init_attention, init_embed, init_mlp,
                                 init_norm, mlp, norm, unembed)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Distribution context threaded through the model (None ⇒ local)."""
    mesh: Any
    dp_axes: tuple            # axes the batch/tokens are sharded over
    tp_axis: Optional[str]    # tensor-parallel axis (FFN hidden / heads)
    ep_axis: Optional[str]    # expert-parallel axis (None ⇒ replicated experts)
    a2a_quant: bool = False   # uint8-wire MoE dispatch (§Perf)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_slot(key, cfg: ModelConfig, mixer_kind: str, ffn_kind: str,
               dtype) -> dict:
    ks = jax.random.split(key, 4)
    slot = {"norm1": init_norm(cfg.d_model, cfg.norm_kind, dtype)}
    if mixer_kind.startswith("attn"):
        slot["attn"] = init_attention(ks[0], cfg, dtype)
    else:
        slot["mamba"] = mb.init_mamba(ks[0], cfg, dtype)
    if cfg.post_norms:
        slot["post_norm1"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
    if ffn_kind != "none":
        slot["norm2"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
        if ffn_kind == "moe":
            slot["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            slot["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
        if cfg.post_norms:
            slot["post_norm2"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
    return slot


def _stack_stages(per_stage: list) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_stage)


def init_lm_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    period = cfg.period
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    n_stages = cfg.num_layers // period
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(period)]
    key, ke, kf = jax.random.split(key, 3)
    params = {"embed": init_embed(ke, cfg, dtype),
              "final_norm": init_norm(cfg.d_model, cfg.norm_kind, dtype)}
    slots = []
    for s_idx, (mk, fk) in enumerate(kinds):
        stages = [_init_slot(jax.random.fold_in(key, st * period + s_idx),
                             cfg, mk, fk, dtype) for st in range(n_stages)]
        slots.append(_stack_stages(stages))
    params["slots"] = tuple(slots)
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                                      attn_every=0, local_global=False,
                                      num_experts=0)
        kenc = jax.random.fold_in(kf, 7)
        enc_slots = [_stack_stages(
            [_init_slot(jax.random.fold_in(kenc, st), enc_cfg, "attn",
                        "dense", dtype) for st in range(cfg.encoder_layers)])]
        cross = [_stack_stages(
            [{"norm": init_norm(cfg.d_model, cfg.norm_kind, dtype),
              "attn": init_attention(jax.random.fold_in(kenc, 1000 + st),
                                     cfg, dtype)}
             for st in range(cfg.num_layers)])]
        params["encoder"] = {"slots": tuple(enc_slots),
                             "final_norm": init_norm(cfg.d_model,
                                                     cfg.norm_kind, dtype)}
        params["cross"] = cross[0]
    return params


def count_lm_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_moe(slot_moe, cfg: ModelConfig, x: jax.Array, mode: str,
               ctx: Optional[ShardCtx]):
    b, s, d = x.shape
    toks = x.reshape(b * s, d)
    if ctx is None:
        y = moe_mod.moe_ffn(slot_moe, cfg, toks, mode=mode, ep_axis=None)
        return y.reshape(b, s, d)

    shard_map = jax.shard_map
    ep = ctx.ep_axis if (ctx.ep_axis and
                         cfg.num_experts %
                         ctx.mesh.shape[ctx.ep_axis] == 0) else None
    tp = ctx.tp_axis
    tp_n = ctx.mesh.shape[tp] if tp else 1
    packed = "up_packed" in slot_moe
    # the expert hidden dim F is TP-sliced only if every F-indexed tensor
    # (up/gate cols, down rows — /32 when bit-packed — and α vectors) splits
    ok = tp and cfg.d_ff % tp_n == 0 and \
        (not packed or (cfg.d_ff // 32) % tp_n == 0)
    tp_eff = tp if ok else None
    sh_ok = tp and cfg.shared_experts and \
        (cfg.d_ff * cfg.shared_experts) % tp_n == 0
    tp_sh = tp if sh_ok else None

    specs = {}
    for name in slot_moe:
        if name in ("up", "gate", "up_packed", "gate_packed", "up_alpha",
                    "gate_alpha"):
            specs[name] = P(ep, None, tp_eff)   # (E, K[/32]|1, F[/32])
        elif name in ("down", "down_packed"):
            specs[name] = P(ep, tp_eff, None)   # (E, F[/32], D)
        elif name == "down_alpha":
            specs[name] = P(ep, None, None)
        elif name in ("shared_up", "shared_gate"):
            specs[name] = P(None, tp_sh)
        elif name == "shared_down":
            specs[name] = P(tp_sh, None)
        elif name == "router":
            specs[name] = P(None, None)
        else:
            specs[name] = P()

    def inner(p_local, t_local):
        y = moe_mod.moe_ffn(p_local, cfg, t_local, mode=mode, ep_axis=ep,
                            tp_axis=tp_eff, shared_tp=tp_sh,
                            a2a_quant=ctx.a2a_quant)
        return y

    y = shard_map(inner, mesh=ctx.mesh,
                  in_specs=(specs, P(ctx.dp_axes, None)),
                  out_specs=P(ctx.dp_axes, None),
                  check_vma=False)(slot_moe, toks)
    return y.reshape(b, s, d)


def _apply_slot(slot: dict, cfg: ModelConfig, x: jax.Array, *,
                mixer_kind: str, ffn_kind: str, mode: str,
                positions: jax.Array, ctx: Optional[ShardCtx]) -> jax.Array:
    h = norm(slot["norm1"], x, cfg.norm_kind)
    if mixer_kind.startswith("attn"):
        window = 0
        if mixer_kind == "attn_local" or (cfg.sliding_window and
                                          not cfg.local_global):
            window = cfg.sliding_window
        out = attention(slot["attn"], cfg, h, mode=mode, causal=True,
                        window=window, positions=positions)
    else:
        mixer = (mb.mamba2_mixer if cfg.ssm_kind == "mamba2"
                 else mb.mamba1_mixer)
        out = mixer(slot["mamba"], cfg, h, mode=mode)
    if cfg.post_norms:
        out = norm(slot["post_norm1"], out, cfg.norm_kind)
    x = x + out.astype(x.dtype)          # keep the scan carry dtype stable
    if ffn_kind != "none":
        h = norm(slot["norm2"], x, cfg.norm_kind)
        if ffn_kind == "moe":
            out = _apply_moe(slot["moe"], cfg, h, mode, ctx)
        else:
            out = mlp(slot["mlp"], cfg, h, mode)
        if cfg.post_norms:
            out = norm(slot["post_norm2"], out, cfg.norm_kind)
        x = x + out.astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Forward (train/eval)
# ---------------------------------------------------------------------------

def lm_forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
               mode: str = "float", prefix_embeds: Optional[jax.Array] = None,
               encoder_embeds: Optional[jax.Array] = None,
               ctx: Optional[ShardCtx] = None,
               remat: bool = False) -> jax.Array:
    """tokens (B, S) int32 → logits (B, S_total, vocab).

    prefix_embeds: (B, S_p, D) modality stub (vision patches / audio frames)
    prepended to the token embeddings (internvl2 path).
    encoder_embeds: (B, S_enc, D) encoder *input* features for enc-dec
    (seamless path) — runs the encoder stack, then decoder cross-attends.
    """
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.period)]
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    enc_out = None
    if encoder_embeds is not None:
        enc_out = encode(cfg, params, encoder_embeds, mode=mode)

    cross = params.get("cross")

    def stage(x, slot_stack):
        for i, (mk, fk) in enumerate(kinds):
            x = _apply_slot(slot_stack[i], cfg, x, mixer_kind=mk, ffn_kind=fk,
                            mode=mode, positions=positions, ctx=ctx)
        return x, None

    if enc_out is None and cross is None:
        body = jax.checkpoint(stage) if remat else stage
        x, _ = jax.lax.scan(body, x, params["slots"])
    else:
        # enc-dec: interleave cross-attention after each decoder self-attn
        def stage_cross(x, slots_and_cross):
            slot_stack, cr = slots_and_cross
            for i, (mk, fk) in enumerate(kinds):
                x = _apply_slot(slot_stack[i], cfg, x, mixer_kind=mk,
                                ffn_kind=fk, mode=mode, positions=positions,
                                ctx=ctx)
            h = norm(cr["norm"], x, cfg.norm_kind)
            x = x + attention(cr["attn"], cfg, h, mode=mode, causal=False,
                              positions=positions,
                              kv_x=enc_out).astype(x.dtype)
            return x, None
        body = jax.checkpoint(stage_cross) if remat else stage_cross
        x, _ = jax.lax.scan(body, x, (params["slots"], cross))

    x = norm(params["final_norm"], x, cfg.norm_kind)
    return unembed(params["embed"], cfg, x)


def encode(cfg: ModelConfig, params: dict, feats: jax.Array, *,
           mode: str = "float") -> jax.Array:
    """Bidirectional encoder over stub features (B, S_enc, D)."""
    enc = params["encoder"]
    b, s, _ = feats.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    dtype = params["embed"]["emb"].dtype

    def stage(x, slot_stack):
        h = norm(slot_stack[0]["norm1"], x, cfg.norm_kind)
        out = attention(slot_stack[0]["attn"], cfg, h, mode=mode,
                        causal=False, positions=positions)
        x = x + out.astype(x.dtype)
        h = norm(slot_stack[0]["norm2"], x, cfg.norm_kind)
        x = x + mlp(slot_stack[0]["mlp"], cfg, h, mode).astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(stage, feats.astype(dtype), enc["slots"])
    return norm(enc["final_norm"], x, cfg.norm_kind)
