"""The paper's W1A8 YOLOv3-tiny-like detector (Table 1), three datapaths:

  float   — QAT training / eval model (the "ONNX Runtime" oracle role),
  int     — numpy int64 bit-exact deployment pipeline (the "RTL" role):
            Q0.8 input, Q5.11/Q2.14 Conv1, sign-PE with fixed-point Mul_prev
            fused into accumulation, (mult, shift) Div_current post-processing,
            Q1.15/Q4.12 Conv11 emitting signed Q*.15 raw (int32/2^15),
  kernel  — Pallas streaming path (bit-packed weights, fused epilogues).

Input 320×320×3 → output 10×10×75 (y/x/channel), 0.74 M params, 0.098 GFLOPs
under the paper's full-precision-ops convention (binary ops discounted).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.qtensor import QTensor
from repro.core.quant import (ACT_QMAX, binarize_ste, binarize_weight,
                              lsq_fake_quant, lsq_grad_scale, quantize_act)
from repro.kernels import config as _cfg
from repro.kernels.config import KernelConfig
from repro.kernels.w1a8_conv import ops as conv_ops
from repro.kernels.w1a8_matmul import ops as mm_ops

PROFILES = ("tuned", "default", "interpret")


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    kind: str          # "std" | "w1a8"
    cin: int
    cout: int
    ksize: int
    pool: bool


# Table 1, exactly.
YOLO_LAYERS = (
    ConvSpec("conv1", "std", 3, 16, 3, True),
    ConvSpec("conv2", "w1a8", 16, 32, 3, True),
    ConvSpec("conv3", "w1a8", 32, 64, 3, True),
    ConvSpec("conv4", "w1a8", 64, 128, 3, True),
    ConvSpec("conv5", "w1a8", 128, 128, 3, False),
    ConvSpec("conv6", "w1a8", 128, 128, 3, False),
    ConvSpec("conv7", "w1a8", 128, 128, 3, True),
    ConvSpec("conv8", "w1a8", 128, 128, 3, False),
    ConvSpec("conv9", "w1a8", 128, 64, 1, False),
    ConvSpec("conv10", "w1a8", 64, 64, 3, False),
    ConvSpec("conv11", "std", 64, 75, 1, False),
)

INPUT_SIZE = 320
NUM_ANCHORS, NUM_CLASSES = 3, 20          # 75 = 3 * (5 + 20), VOC
GRID = 10


# ---------------------------------------------------------------------------
# Parameter init / counting
# ---------------------------------------------------------------------------

def init_yolo_params(key: jax.Array, dtype=jnp.float32) -> dict:
    params = {}
    for spec in YOLO_LAYERS:
        key, sub = jax.random.split(key)
        fan_in = spec.ksize * spec.ksize * spec.cin
        w = jax.random.normal(sub, (spec.ksize, spec.ksize, spec.cin,
                                    spec.cout), dtype) / np.sqrt(fan_in)
        layer = {"w": w, "b": jnp.zeros((spec.cout,), dtype)}
        if spec.kind == "w1a8":
            # per-input-channel LSQ step for this layer's input (Mul_prev)
            layer["act_step"] = jnp.full((spec.cin,), 0.05, dtype)
        params[spec.name] = layer
    # conv11's input quantizer (its Mul_prev); output stays raw (Q*.15)
    params["conv11"]["act_step"] = jnp.full((64,), 0.05, dtype)
    return params


def count_params() -> dict:
    """Parameter count (weights + biases), matching the paper's 0.74 M."""
    weights = sum(s.ksize ** 2 * s.cin * s.cout for s in YOLO_LAYERS)
    biases = sum(s.cout for s in YOLO_LAYERS)
    return {"weights": weights, "biases": biases, "total": weights + biases}


def spatial_sizes(input_size: int = INPUT_SIZE) -> dict:
    """Input H=W per layer (Table 2 progression) for one resolution bucket.

    Any multiple of 32 (= 2^5, one halving per pool) keeps every pooled
    plane even, so the same layer stack serves 256/320/416/... buckets."""
    if input_size <= 0 or input_size % 32:
        raise ValueError(f"input size must be a positive multiple of 32 "
                         f"(5 pools), got {input_size}")
    sizes, h = {}, input_size
    for s in YOLO_LAYERS:
        sizes[s.name] = h
        if s.pool:
            h //= 2
    return sizes


def count_gflops() -> dict:
    """FLOPs under both conventions.

    `paper` — full-precision ops only (the paper's 0.098 GFLOPs convention):
    Conv1/Conv11 MACs×2 + their bias adds + maxpool compares + W1A8
    post-processing (scale+round ≈ 2 ops/output) + Mul_prev prologue.
    `total` — everything at face value incl. binary-weight MACs×2.
    """
    sizes = spatial_sizes()
    full, binary, aux = 0, 0, 0
    for s in YOLO_LAYERS:
        hw = sizes[s.name] ** 2
        macs = s.ksize ** 2 * s.cin * s.cout * hw
        if s.kind == "std":
            full += 2 * macs + s.cout * hw          # MACs + bias
        else:
            binary += 2 * macs                       # sign-controlled add/sub
            aux += s.cin * hw                        # Mul_prev m_i·a_i (PE prologue)
            aux += 3 * s.cout * hw                   # post: scale, bias, round/clip
        if s.pool:
            aux += 3 * s.cout * (sizes[s.name] // 2) ** 2  # 2×2 max = 3 cmp
    return {"paper_gflops": (full + aux) / 1e9,
            "total_gflops": (full + binary + aux) / 1e9,
            "binary_discount64_gflops": (full + aux + binary / 64) / 1e9}


# ---------------------------------------------------------------------------
# Float forward (QAT train / eval oracle)
# ---------------------------------------------------------------------------

def _conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    pad = "SAME" if w.shape[0] == 3 else "VALID"
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), pad, dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def yolo_forward_float(params: dict, images: jax.Array, *,
                       train: bool = False) -> jax.Array:
    """images: (B, 320, 320, 3) in [0, 1]. Returns (B, 10, 10, 75) raw head."""
    x = images
    for spec in YOLO_LAYERS:
        p = params[spec.name]
        if spec.kind == "std":
            if spec.name == "conv1":
                w = fxp.CONV1_W.roundtrip(p["w"]) if not train else p["w"]
                b = fxp.CONV1_B.roundtrip(p["b"]) if not train else p["b"]
                x = _conv2d(x, w) + b
                x = jax.nn.relu(x)
            else:  # conv11 detection head: quantize input, raw output
                if train:
                    gs = lsq_grad_scale(x.size // x.shape[-1])
                    xq = lsq_fake_quant(x, p["act_step"], jnp.asarray(gs, x.dtype))
                    x = _conv2d(xq, p["w"]) + p["b"]
                else:
                    xq = quantize_act(x, p["act_step"]) * p["act_step"]
                    w = fxp.CONV11_W.roundtrip(p["w"])
                    b = fxp.CONV11_B.roundtrip(p["b"])
                    x = _conv2d(xq, w) + b
        else:
            if train:
                gs = lsq_grad_scale(x.size // x.shape[-1])
                xq = lsq_fake_quant(x, p["act_step"], jnp.asarray(gs, x.dtype))
                wb = binarize_ste(p["w"])
            else:
                xq = quantize_act(x, p["act_step"]) * p["act_step"]
                wb = binarize_weight(p["w"])
            alpha = jax.lax.stop_gradient(
                jnp.mean(jnp.abs(p["w"]), axis=(0, 1, 2)))
            x = _conv2d(xq, wb) * alpha + p["b"]
            x = jax.nn.relu(x)
        if spec.pool:
            x = _maxpool2(x)
    return x


def calibrate_yolo(params: dict, images: jax.Array, *,
                   per_channel: bool = True) -> dict:
    """Range-calibrate every activation quantizer (LSQ init, per channel).

    Runs the float datapath layer by layer, setting each act_step so the
    observed per-channel max maps to code 255 — the deployment-time
    equivalent of LSQ's learned steps for an untrained/just-initialized net.

    ``per_channel=False`` calibrates one step per tensor (the scalar max,
    broadcast over channels) — the uniform-Mul_prev regime the FPGA PE
    actually implements (one fixed-point Mul_prev constant per layer ROM).
    Per-channel artifacts serve through every accum mode: the XNOR-popcount
    path folds the per-channel step ratio into the producer's epilogue
    (`yolo_forward_kernel`), so ``per_channel=True`` no longer restricts
    kernel selection.
    """
    params = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy
    x = images
    for spec in YOLO_LAYERS:
        p = params[spec.name]
        if spec.kind == "w1a8" or spec.name == "conv11":
            axes = (0, 1, 2) if per_channel else None
            cmax = jnp.max(jnp.abs(x), axis=axes)
            step = jnp.maximum(cmax / ACT_QMAX, 1e-4)
            if not per_channel:
                step = jnp.broadcast_to(step, (x.shape[-1],))
            p = dict(p)
            p["act_step"] = step.astype(jnp.float32)
            params[spec.name] = p
        if spec.kind == "std":
            if spec.name == "conv1":
                x = jax.nn.relu(_conv2d(x, fxp.CONV1_W.roundtrip(p["w"]))
                                + fxp.CONV1_B.roundtrip(p["b"]))
            else:
                xq = quantize_act(x, p["act_step"]) * p["act_step"]
                x = _conv2d(xq, fxp.CONV11_W.roundtrip(p["w"])) \
                    + fxp.CONV11_B.roundtrip(p["b"])
        else:
            xq = quantize_act(x, p["act_step"]) * p["act_step"]
            alpha = jnp.mean(jnp.abs(p["w"]), axis=(0, 1, 2))
            x = jax.nn.relu(_conv2d(xq, binarize_weight(p["w"])) * alpha
                            + p["b"])
        if spec.pool:
            x = _maxpool2(x)
    return params


# ---------------------------------------------------------------------------
# Deployment: parameter extraction & fixed-point conversion (paper §4)
# ---------------------------------------------------------------------------

FM = 16  # fractional bits of the fixed-point Mul_prev inside the PE


def _requant_multshift(scale: np.ndarray, bits: int = 15):
    """scale → (mult int, rshift) with mult in [2^(bits-1), 2^bits):
    x·scale ≈ (x·mult) >> rshift  — the ONNX-style normalized requantizer."""
    scale = np.asarray(scale, np.float64)
    out_m = np.zeros(scale.shape, np.int64)
    out_s = np.zeros(scale.shape, np.int64)
    nz = scale > 0
    exp = np.floor(np.log2(scale[nz]))
    rshift = (bits - 1 - exp).astype(np.int64)
    mult = np.round(scale[nz] * (2.0 ** rshift)).astype(np.int64)
    # rounding may push mult to 2^bits; renormalize
    over = mult >= (1 << bits)
    mult[over] >>= 1
    rshift[over] -= 1
    out_m[nz], out_s[nz] = mult, rshift
    return out_m, out_s


def deploy_yolo(params: dict) -> dict:
    """Training params → integer deployment artifact (numpy, 'COE' role)."""
    art = {"layers": []}
    steps_next = {}  # step of each layer's *output* = next quant layer's input step
    for i, spec in enumerate(YOLO_LAYERS[:-1]):
        nxt = params[YOLO_LAYERS[i + 1].name]
        steps_next[spec.name] = np.asarray(
            jnp.broadcast_to(nxt["act_step"], (YOLO_LAYERS[i + 1].cin,)),
            np.float64)
    for spec in YOLO_LAYERS:
        p = {k: np.asarray(v, np.float64) for k, v in params[spec.name].items()}
        entry = {"spec": spec}
        if spec.name == "conv1":
            entry["w_raw"] = np.asarray(fxp.CONV1_W.quantize(
                jnp.asarray(p["w"], jnp.float32)), np.int64)
            entry["b_raw"] = np.asarray(fxp.CONV1_B.quantize(
                jnp.asarray(p["b"], jnp.float32)), np.int64)
            # acc scale 2^-19 (Q0.8 input × Q5.11 weights); bias at 2^-14 → <<5
            # post: /step_next ⇒ scale = 2^-19/step
            mult, shift = _requant_multshift(2.0 ** -19 / steps_next["conv1"])
            entry["post_mult"], entry["post_shift"] = mult, shift
        elif spec.name == "conv11":
            entry["w_raw"] = np.asarray(fxp.CONV11_W.quantize(
                jnp.asarray(p["w"], jnp.float32)), np.int64)
            entry["b_raw"] = np.asarray(fxp.CONV11_B.quantize(
                jnp.asarray(p["b"], jnp.float32)), np.int64)
            entry["m_raw"] = np.round(
                np.broadcast_to(p["act_step"], (spec.cin,)) * 2 ** FM
            ).astype(np.int64)
        else:
            w2 = p["w"].reshape(-1, spec.cout)
            entry["signs"] = np.where(w2 >= 0, 1, -1).astype(np.int64)
            alpha = np.mean(np.abs(w2), axis=0)
            entry["m_raw"] = np.round(
                np.broadcast_to(p["act_step"], (spec.cin,)) * 2 ** FM
            ).astype(np.int64)
            # post: y = acc·2^-FM·α + b, then /step_next — single fused
            # rounding: q = rshift(acc·mult + b_preshifted, shift)
            scale = alpha * 2.0 ** -FM / steps_next[spec.name]
            mult, shift = _requant_multshift(scale)
            entry["post_mult"], entry["post_shift"] = mult, shift
            entry["b_pre"] = np.round(
                p["b"] / steps_next[spec.name] * 2.0 ** shift).astype(np.int64)
        art["layers"].append(entry)
    return art


def _rshift_round(x: np.ndarray, shift: np.ndarray) -> np.ndarray:
    """Per-element rounding right-shift, half away from zero (RTL rounder)."""
    x = np.asarray(x, np.int64)
    half = np.where(shift > 0, np.int64(1) << np.maximum(shift - 1, 0), 0)
    mag = np.abs(x) + half
    return np.sign(x) * (mag >> shift)


def _im2col_np(x: np.ndarray, k: int) -> np.ndarray:
    b, h, w, c = x.shape
    if k == 1:
        return x.reshape(b, h, w, c)
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, dy:dy + h, dx:dx + w, :] for dy in range(3) for dx in range(3)]
    return np.concatenate(cols, axis=-1)


def yolo_forward_int(art: dict, images_u8: np.ndarray) -> np.ndarray:
    """Bit-exact integer pipeline (the RTL-analogue datapath).

    images_u8: (B, 320, 320, 3) uint8 raw pixels (Q0.8 codes, value = px/256).
    Returns (B, 10, 10, 75) int64 raw head output at Q*.15 (float = raw/2^15).
    """
    x = images_u8.astype(np.int64)                 # codes; scale 2^-8
    for entry in art["layers"]:
        spec: ConvSpec = entry["spec"]
        if spec.name == "conv1":
            cols = _im2col_np(x, 3)                                # (B,H,W,27)
            wf = entry["w_raw"].reshape(-1, spec.cout)             # (27,16) Q5.11
            acc = cols @ wf                                        # scale 2^-19
            acc = acc + (entry["b_raw"] << 5)                      # Q2.14 → 2^-19
            acc = np.maximum(acc, 0)                               # ReLU
            q = _rshift_round(acc * entry["post_mult"], entry["post_shift"])
            x = np.clip(q, 0, ACT_QMAX)
        elif spec.name == "conv11":
            cols = _im2col_np(x, spec.ksize)
            m9 = np.tile(entry["m_raw"], spec.ksize ** 2)
            wf = entry["w_raw"].reshape(-1, spec.cout)             # Q1.15
            acc = (cols * m9) @ wf                                 # 2^-(15+FM)
            raw = _rshift_round(acc, FM) + (entry["b_raw"] << 3)   # → Q*.15
            return raw
        else:
            cols = _im2col_np(x, spec.ksize)
            m9 = np.tile(entry["m_raw"], spec.ksize ** 2)
            acc = (cols * m9) @ entry["signs"]     # Eq. 3-4: fused Mul_prev PE
            q = _rshift_round(acc * entry["post_mult"] + entry["b_pre"],
                              entry["post_shift"])
            x = np.clip(q, 0, ACT_QMAX)            # post + ReLU-clip
        if spec.pool:
            b, h, w, c = x.shape
            x = x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Pallas-kernel inference path (packed 1-bit weights, fused epilogues)
# ---------------------------------------------------------------------------

def deploy_yolo_kernel(params: dict) -> dict:
    """Training params → packed-weight artifact for the Pallas path."""
    art = {"layers": []}
    for i, spec in enumerate(YOLO_LAYERS):
        p = params[spec.name]
        entry = {"spec": spec}
        if spec.kind == "std":
            entry["w"] = jnp.asarray(p["w"], jnp.float32)
            entry["b"] = jnp.asarray(p["b"], jnp.float32)
            if spec.name == "conv11":
                entry["step_in"] = jnp.broadcast_to(p["act_step"], (spec.cin,))
        else:
            w2 = p["w"].reshape(-1, spec.cout)
            entry["w_packed"] = (conv_ops.conv_pack_weights(p["w"])
                                 if spec.ksize == 3 else
                                 mm_ops.w1a8_pack_weights(w2))
            entry["alpha"] = jnp.mean(jnp.abs(w2), axis=0).astype(jnp.float32)
            entry["step_in"] = jnp.broadcast_to(
                p["act_step"], (spec.cin,)).astype(jnp.float32)
            entry["b"] = jnp.asarray(p["b"], jnp.float32)
        if spec.name != "conv11":
            nxt = params[YOLO_LAYERS[i + 1].name]
            entry["step_out"] = jnp.broadcast_to(
                nxt["act_step"], (YOLO_LAYERS[i + 1].cin,)).astype(jnp.float32)
        art["layers"].append(entry)
    return art


def build_detector(key: jax.Array, calib_images: jax.Array, *,
                   per_channel: bool = None,
                   profile: str = None,
                   buckets=None) -> tuple:
    """Init + range-calibrate + pack: the serving-deployment recipe.

    calib_images (B, S, S, 3) float in [0, 1]. Returns
    (calibrated float params, deploy_yolo_kernel artifact) — the float
    params stay the verification oracle for the packed path
    (core.verify, DESIGN.md §10). ``per_channel`` defaults to True for
    every profile: per-channel calibration serves through all accum modes,
    including XNOR-popcount (the forward path folds the step ratio into
    the producer's epilogue — DESIGN.md §16), so calibration quality is
    never silently traded for kernel eligibility. ``profile`` names the
    tuning profile the artifact is destined for (recorded for callers; it
    no longer changes calibration).

    ``buckets`` declares the resolution buckets (image sides, each a
    multiple of 32) this artifact will serve, e.g. ``(256, 320, 416)``.
    The packed weights are resolution-independent — the buckets are
    recorded on the artifact (``art["buckets"]``) so `DetectionBackend`
    compiles one fixed-width executable per bucket, all sharing these
    weights. Default: the calibration image size.
    """
    if profile is not None and profile not in PROFILES:
        raise ValueError(f"profile must be one of {PROFILES}, got {profile!r}")
    if per_channel is None:
        per_channel = True
    if buckets is None:
        buckets = (int(calib_images.shape[1]),)
    buckets = tuple(dict.fromkeys(int(b) for b in buckets))
    for b in buckets:
        spatial_sizes(b)                 # validates the ×32 constraint
    params = init_yolo_params(key)
    params = calibrate_yolo(params, calib_images, per_channel=per_channel)
    art = deploy_yolo_kernel(params)
    art["buckets"] = buckets
    return params, art


def art_uniform_steps(art: dict) -> bool:
    """True iff every W1A8 layer's input steps are per-tensor uniform.

    Diagnostic only since the per-channel popcount fold landed: popcount
    is always eligible — uniform artifacts take the bit-exact identity
    fold, per-channel artifacts the producer-side uniformization."""
    for entry in art["layers"][1:-1]:
        steps = np.asarray(entry["step_in"])
        if not np.all(steps == steps.reshape(-1)[0]):
            return False
    return True


def yolo_layer_cells(batch: int = 1) -> list:
    """Structural autotune cells for every W1A8 layer.

    Returns [(layer name, op, dims)] with conv dims (h, w, cin, cout) of
    the input plane and matmul dims (m, k, n), m = batch·h·w. Pooled
    layers contribute both their ``conv3x3_pool`` cell (fused route) and
    the plain ``conv3x3`` cell (unfused route); duplicates across layers
    (conv5/6/8 share a shape) collapse by key.
    """
    sizes = spatial_sizes()
    cells = []
    for spec in YOLO_LAYERS:
        if spec.kind != "w1a8":
            continue
        h = sizes[spec.name]
        if spec.ksize == 3:
            if spec.pool:
                cells.append((spec.name, "conv3x3_pool",
                              (h, h, spec.cin, spec.cout)))
            cells.append((spec.name, "conv3x3", (h, h, spec.cin, spec.cout)))
        else:
            cells.append((spec.name, "matmul",
                          (batch * h * h, spec.cin, spec.cout)))
    return cells


def _layer_config(spec: ConvSpec, h: int, batch: int, *, profile: str,
                  accum, fuse_pool, interpret, table) -> KernelConfig:
    """Resolve one W1A8 layer's KernelConfig under the named profile.

    Explicit ``accum`` / ``fuse_pool`` / ``interpret`` kwargs override the
    profile's choice; "tuned" reads the autotune table (fastest accum —
    popcount is always eligible now that the per-channel fold exists —
    and fused-vs-unfused pool routing from the winning entry),
    "default"/"interpret" reproduce the historical heuristics.
    """
    if spec.ksize == 1:
        op, dims = "matmul", (batch * h * h, spec.cin, spec.cout)
    elif spec.pool:
        op, dims = "conv3x3_pool", (h, h, spec.cin, spec.cout)
    else:
        op, dims = "conv3x3", (h, h, spec.cin, spec.cout)
    if profile == "tuned":
        if accum is not None:
            cfg = _cfg.resolve(op, dims, accum=accum, table=table)
        else:
            cfg = _cfg.resolve_tuned(op, dims, table=table)
    else:
        cfg = KernelConfig(op=op, accum=accum or "dot", source=profile)
    if fuse_pool is not None:
        cfg = cfg.replace(fused=fuse_pool)
    elif profile != "tuned":
        cfg = cfg.replace(fused=False)     # historical default
    if interpret is not None:
        cfg = cfg.replace(interpret=interpret)
    elif profile == "interpret":
        cfg = cfg.replace(interpret=True)
    return cfg.replace(out_step=1.0)


def yolo_forward_kernel(art: dict, images: jax.Array, *,
                        profile: str = None,
                        interpret: bool = None,
                        fuse_pool: bool = None,
                        accum: str = None) -> jax.Array:
    """Pallas streaming path. images (B,S,S,3) in [0,1] → (B,S/32,S/32,75)
    f32, for any bucket size S that is a multiple of 32 (default deployment
    S=320 → 10×10 grid). The layer stack, packed weights and per-layer
    configs are resolution-independent; only the spatial plan varies.

    Inter-layer tensors are uint8-code QTensors (requantized in each
    kernel's epilogue) — HBM activation traffic is 1 byte/elem, the
    streaming analogue; the codes+step pair crosses every layer boundary
    as one object.

    Per-layer launch configuration comes from ``profile``:

    * ``"interpret"`` (default) — heuristic tiles, interpret-mode Pallas;
      today's behavior everywhere.
    * ``"default"`` — heuristic tiles, interpret auto-resolved from the
      backend (compiled on real TPUs).
    * ``"tuned"`` — per-layer winners from the committed autotune table
      (`kernels/config.resolve`, exact → nearest-shape → heuristic),
      including fastest-accum selection and the fused-vs-unfused pool
      routing the table measured.

    ``fuse_pool`` routes pooled W1A8 layers (conv2–4, conv7) through the
    fused conv+requant+MaxPool kernel (§5.2 Post+MaxPool stage chain) —
    bit-exact vs the unfused path, in both accum modes. ``accum="popcount"``
    contracts every W1A8 layer in the binary domain (XNOR-popcount); a
    per-channel-calibrated artifact serves through it via the producer-side
    step fold — when a layer's consumer contracts with popcount, the
    producer's epilogue requantizes onto the uniformized step
    s̄ = max_c s_c (div_eff = α/s̄, b_eff = b/s̄: one rounding, no extra
    clipping since s̄ ≥ s_c), so the codes reaching the bit-packed
    accumulation already sit on a per-tensor grid (DESIGN.md §16). All
    three kwargs override the profile.
    """
    if profile is None:
        profile = "interpret"
    if profile not in PROFILES:
        raise ValueError(f"profile must be one of {PROFILES}, got {profile!r}")
    layers = art["layers"]
    table = _cfg.load_table() if profile == "tuned" else None
    sizes = spatial_sizes(images.shape[1])          # static under jit
    batch = images.shape[0]
    w1a8 = layers[1:-1]
    cfgs = [_layer_config(e["spec"], sizes[e["spec"].name], batch,
                          profile=profile, accum=accum, fuse_pool=fuse_pool,
                          interpret=interpret, table=table)
            for e in w1a8]

    def boundary_step(step_out, i):
        # the step the producer's epilogue quantizes ONTO; popcount
        # consumers get the uniformized s̄ = max_c s_c (producer-side fold)
        if i < len(cfgs) and cfgs[i].accum == "popcount":
            return jnp.broadcast_to(jnp.max(step_out), jnp.shape(step_out))
        return step_out

    # conv1 (std, fixed-point-rounded weights) in f32, then quantize to codes.
    w1 = fxp.CONV1_W.roundtrip(layers[0]["w"])
    b1 = fxp.CONV1_B.roundtrip(layers[0]["b"])
    x = jax.nn.relu(_conv2d(images, w1) + b1)
    x = _maxpool2(x)
    qx = QTensor.quantize_u8(x, boundary_step(layers[0]["step_out"], 0),
                             axis=-1)

    for i, entry in enumerate(w1a8):
        spec: ConvSpec = entry["spec"]
        cfg = cfgs[i]
        # Mul_prev = this layer's input steps (= qx.scale: the QTensor
        # carries exactly the dequant context the next kernel fuses);
        # per-channel requant is folded into the epilogue:
        # q = round(acc·(α/s_next) + b/s_next), out_step=1.
        mul_prev = qx.scale
        s_next = boundary_step(entry["step_out"], i + 1)   # (cout,) vector
        div_eff = entry["alpha"] / s_next
        b_eff = entry["b"] / s_next
        if spec.ksize == 3 and spec.pool:
            codes = conv_ops.w1a8_conv3x3_pool(
                qx.data, entry["w_packed"], mul_prev, div_eff, b_eff,
                cin=spec.cin, config=cfg)
            qx = QTensor.from_codes(codes, s_next, axis=-1)
            continue
        if spec.ksize == 3:
            out = conv_ops.w1a8_conv3x3(
                qx.data, entry["w_packed"], mul_prev, div_eff, b_eff,
                cin=spec.cin, config=cfg)
        else:
            b, h, w, _ = qx.data.shape
            out = mm_ops.w1a8_matmul(
                qx.data.reshape(b * h * w, spec.cin), entry["w_packed"],
                mul_prev, div_eff, b_eff, k=spec.cin, config=cfg)
            out = out.reshape(b, h, w, spec.cout)
        qx = QTensor.from_codes(out, s_next, axis=-1)

    # conv11 detection head (std 1×1, fixed-point weights) on dequant codes.
    last = layers[-1]
    xq = qx.dequantize()
    w11 = fxp.CONV11_W.roundtrip(last["w"])
    b11 = fxp.CONV11_B.roundtrip(last["b"])
    return _conv2d(xq, w11) + b11
