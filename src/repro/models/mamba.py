"""State-space mixers: Mamba-2 (SSD, chunked dual form) and Mamba-1
(selective scan), both with O(1)-state decode steps.

Training form processes the sequence in chunks with a `lax.scan` carrying the
inter-chunk SSM state — HLO stays compact and per-chunk buffers bound VMEM/HBM
pressure (the TPU analogue of the fused-SRAM selective-scan kernel). Channel
dims are TP-shardable: in_proj column-parallel, out_proj row-parallel, the
scan itself is per-channel (no cross-channel mixing).

mamba2-1.3b uses SSD; jamba's mamba layers use Mamba-1 (d_state 16), per
their papers.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ModelConfig, init_linear, linear


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d, di, n = cfg.d_model, d_inner(cfg), cfg.ssm_state
    w1a8 = cfg.w1a8_body
    if cfg.ssm_kind == "mamba2":
        h = di // cfg.ssm_headdim
        g = 1                                    # single B/C group
        proj_out = 2 * di + 2 * g * n + h        # z, x, B, C, dt
        p = {
            "in_proj": init_linear(ks[0], d, proj_out, w1a8=w1a8, dtype=dtype),
            "out_proj": init_linear(ks[1], di, d, w1a8=w1a8, dtype=dtype),
            "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv,
                                                di + 2 * g * n), dtype) * 0.1,
            "conv_b": jnp.zeros((di + 2 * g * n,), dtype),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(dtype)),
            "D": jnp.ones((h,), dtype),
            "dt_bias": jnp.zeros((h,), dtype),
            "norm_scale": jnp.ones((di,), dtype),
        }
    else:  # mamba1
        dt_rank = max(1, math.ceil(d / 16))
        p = {
            "in_proj": init_linear(ks[0], d, 2 * di, w1a8=w1a8, dtype=dtype),
            "out_proj": init_linear(ks[1], di, d, w1a8=w1a8, dtype=dtype),
            "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, di), dtype) * 0.1,
            "conv_b": jnp.zeros((di,), dtype),
            "x_proj": init_linear(ks[3], di, dt_rank + 2 * n, w1a8=False,
                                  dtype=dtype),
            "dt_proj": init_linear(ks[4], dt_rank, di, w1a8=False,
                                   bias=True, dtype=dtype),
            "A_log": jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=dtype)), (di, n)).copy(),
            "D": jnp.ones((di,), dtype),
        }
    return p


# ---------------------------------------------------------------------------
# Causal depthwise conv (width W) + cache-friendly step form
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (B,S,C), w (W,C): y[t] = Σ_i w[i]·x[t-W+1+i] + b, zero history."""
    width, s = w.shape[0], x.shape[1]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    acc = sum(xp[:, i:i + s, :] * w[i] for i in range(width))
    return jax.nn.silu(acc + b)


def causal_conv_step(x_new: jax.Array, conv_state: jax.Array, w: jax.Array,
                     b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decode step. x_new (B,C); conv_state (B,W-1,C) past inputs."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)
    y = jnp.einsum("bwc,wc->bc", window, w) + b
    return jax.nn.silu(y), window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2: SSD chunked scan
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, *, chunk: int = 128,
                init_state: Optional[jax.Array] = None):
    """SSD dual form. x (B,S,H,P), dt (B,S,H) ≥0, a (H,) <0,
    bmat/cmat (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s))
        x = jnp.pad(x, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        bmat = jnp.pad(bmat, pad + ((0, 0),))
        cmat = jnp.pad(cmat, pad + ((0, 0),))
    nc = s_pad // chunk
    xs = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dts = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bs = bmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cs = cmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        xc, dtc, bc, cc = inp                 # (B,l,H,P), (B,l,H), (B,l,N)
        da = dtc * a                          # (B,l,H)
        da_cs = jnp.cumsum(da, axis=1)
        xdt = xc * dtc[..., None]
        # intra-chunk (quadratic) term
        scores = jnp.einsum("bin,bjn->bij", cc, bc)         # (B,l,l)
        diff = da_cs[:, :, None, :] - da_cs[:, None, :, :]
        # mask BEFORE exp: where-after-exp leaks inf·0 = NaN into the vjp
        lmat = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", scores, lmat, xdt)
        # inter-chunk: contribution of the carried state
        state_decay = jnp.exp(da_cs)                         # (B,l,H)
        y_off = jnp.einsum("bin,bhpn,bih->bihp", cc, state, state_decay)
        # new state: decay-weighted sum of this chunk + decayed carry
        tail = jnp.exp(da_cs[:, -1:, :] - da_cs)             # (B,l,H)
        chunk_state = jnp.einsum("bln,blhp,blh->bhpn", bc, xdt, tail)
        new_state = state * jnp.exp(da_cs[:, -1, :])[..., None, None] \
            + chunk_state
        return new_state, y_diag + y_off

    state0 = init_state if init_state is not None else \
        jnp.zeros((bsz, h, p, n), x.dtype)
    final, ys = jax.lax.scan(step, state0, (xs, dts, bs, cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s_pad, h, p)[:, :s]
    return y, final


def mamba2_mixer(p: dict, cfg: ModelConfig, xin: jax.Array, *,
                 mode: str) -> jax.Array:
    """Full Mamba-2 block: in_proj → conv → SSD → gate → norm → out_proj."""
    bsz, s, _ = xin.shape
    di, n = d_inner(cfg), cfg.ssm_state
    h = di // cfg.ssm_headdim
    proj = linear(p["in_proj"], xin, mode)
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (B,S,H)
    a = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs.reshape(bsz, s, h, cfg.ssm_headdim), dt, a,
                       bmat, cmat)
    y = y + xs.reshape(bsz, s, h, cfg.ssm_headdim) * p["D"][:, None]
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * p["norm_scale"]).astype(xin.dtype)
    return linear(p["out_proj"], y, mode)


def mamba2_prefill(p: dict, cfg: ModelConfig, xin: jax.Array, *,
                   mode: str):
    """Like mamba2_mixer but also returns the decode cache after the prompt."""
    bsz, s, _ = xin.shape
    di, n = d_inner(cfg), cfg.ssm_state
    h = di // cfg.ssm_headdim
    proj = linear(p["in_proj"], xin, mode)
    z, xbc_raw, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs.reshape(bsz, s, h, cfg.ssm_headdim), dt, a,
                           bmat, cmat)
    y = y + xs.reshape(bsz, s, h, cfg.ssm_headdim) * p["D"][:, None]
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * p["norm_scale"]).astype(xin.dtype)
    w = cfg.ssm_conv
    conv_state = xbc_raw[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
        xbc_raw, ((0, 0), (w - 1 - s, 0), (0, 0)))
    return linear(p["out_proj"], y, mode), {"conv": conv_state, "ssm": state}


def mamba1_prefill(p: dict, cfg: ModelConfig, xin: jax.Array, *, mode: str):
    bsz, s, _ = xin.shape
    di, n = d_inner(cfg), cfg.ssm_state
    xz = linear(p["in_proj"], xin, mode)
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = causal_conv(xs_raw, p["conv_w"], p["conv_b"])
    proj = linear(p["x_proj"], xs, "float")
    dt_rank = proj.shape[-1] - 2 * n
    dt_lr, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_lr, "float"))
    a = -jnp.exp(p["A_log"])
    y, state = selective_scan_chunked(xs, dt, a, bmat, cmat)
    y = (y + xs * p["D"]) * jax.nn.silu(z)
    w = cfg.ssm_conv
    conv_state = xs_raw[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
        xs_raw, ((0, 0), (w - 1 - s, 0), (0, 0)))
    return linear(p["out_proj"], y, mode), {"conv": conv_state, "ssm": state}


def mamba2_decode_step(p: dict, cfg: ModelConfig, xin: jax.Array,
                       cache: dict, mode: str) -> Tuple[jax.Array, dict]:
    """One-token recurrent update. xin (B,1,D); cache {conv (B,W-1,C),
    ssm (B,H,P,N)} — O(1) memory in sequence length."""
    bsz = xin.shape[0]
    di, n = d_inner(cfg), cfg.ssm_state
    h, pd = di // cfg.ssm_headdim, cfg.ssm_headdim
    proj = linear(p["in_proj"], xin[:, 0, :], mode)
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_state = causal_conv_step(xbc, cache["conv"], p["conv_w"],
                                       p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (B,H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                     # (B,H)
    xh = xs.reshape(bsz, h, pd)
    ssm = cache["ssm"] * da[..., None, None] + \
        jnp.einsum("bhp,bn,bh->bhpn", xh, bmat, dt)
    y = jnp.einsum("bhpn,bn->bhp", ssm, cmat) + xh * p["D"][:, None]
    y = y.reshape(bsz, di) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * p["norm_scale"]).astype(xin.dtype)
    out = linear(p["out_proj"], y, mode)
    return out[:, None, :], {"conv": conv_state, "ssm": ssm}


# ---------------------------------------------------------------------------
# Mamba-1: chunked selective scan (jamba's mixer, d_state 16)
# ---------------------------------------------------------------------------

def selective_scan_chunked(u: jax.Array, dt: jax.Array, a: jax.Array,
                           bmat: jax.Array, cmat: jax.Array, *,
                           chunk: int = 128,
                           init_state: Optional[jax.Array] = None):
    """u/dt (B,S,C), a (C,N), bmat/cmat (B,S,N) → (y (B,S,C), state (B,C,N)).

    h_t = exp(dt·a)·h_{t-1} + dt·b_t·u_t ; y_t = ⟨h_t, c_t⟩.
    Outer lax.scan over chunks, inner associative scan within a chunk.
    """
    bsz, s, c = u.shape
    n = bmat.shape[-1]
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        u, dt = jnp.pad(u, pad), jnp.pad(dt, pad)
        bmat, cmat = jnp.pad(bmat, pad), jnp.pad(cmat, pad)
    nc = s_pad // chunk
    us = u.reshape(bsz, nc, chunk, c).transpose(1, 0, 2, 3)
    dts = dt.reshape(bsz, nc, chunk, c).transpose(1, 0, 2, 3)
    bs = bmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cs = cmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    def step(state, inp):
        uc, dtc, bc, cc = inp
        da = jnp.exp(dtc[..., None] * a)                     # (B,l,C,N)
        dbu = dtc[..., None] * bc[:, :, None, :] * uc[..., None]
        aa, hh = jax.lax.associative_scan(assoc, (da, dbu), axis=1)
        hh = hh + aa * state[:, None]                        # inject carry
        y = jnp.einsum("blcn,bln->blc", hh, cc)
        return hh[:, -1], y

    state0 = init_state if init_state is not None else \
        jnp.zeros((bsz, c, n), u.dtype)
    final, ys = jax.lax.scan(step, state0, (us, dts, bs, cs))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s_pad, c)[:, :s]
    return y, final


def mamba1_mixer(p: dict, cfg: ModelConfig, xin: jax.Array, *,
                 mode: str) -> jax.Array:
    bsz, s, _ = xin.shape
    di, n = d_inner(cfg), cfg.ssm_state
    xz = linear(p["in_proj"], xin, mode)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = causal_conv(xs, p["conv_w"], p["conv_b"])
    proj = linear(p["x_proj"], xs, "float")
    dt_rank = proj.shape[-1] - 2 * n
    dt_lr, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_lr, "float"))
    a = -jnp.exp(p["A_log"])
    y, _ = selective_scan_chunked(xs, dt, a, bmat, cmat)
    y = y + xs * p["D"]
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y, mode)


def mamba1_decode_step(p: dict, cfg: ModelConfig, xin: jax.Array,
                       cache: dict, mode: str) -> Tuple[jax.Array, dict]:
    di, n = d_inner(cfg), cfg.ssm_state
    xz = linear(p["in_proj"], xin[:, 0, :], mode)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = causal_conv_step(xs, cache["conv"], p["conv_w"],
                                      p["conv_b"])
    proj = linear(p["x_proj"], xs, "float")
    dt_rank = proj.shape[-1] - 2 * n
    dt_lr, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_lr, "float"))   # (B,C)
    a = -jnp.exp(p["A_log"])                                     # (C,N)
    da = jnp.exp(dt[..., None] * a)
    ssm = cache["ssm"] * da + dt[..., None] * bmat[:, None, :] * xs[..., None]
    y = jnp.einsum("bcn,bn->bc", ssm, cmat) + xs * p["D"]
    y = y * jax.nn.silu(z)
    out = linear(p["out_proj"], y, mode)
    return out[:, None, :], {"conv": conv_state, "ssm": ssm}


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    di, n = d_inner(cfg), cfg.ssm_state
    if cfg.ssm_kind == "mamba2":
        h, pd = di // cfg.ssm_headdim, cfg.ssm_headdim
        conv_c = di + 2 * n
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_c), dtype),
                "ssm": jnp.zeros((batch, h, pd, n), dtype)}
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, n), dtype)}
