"""GPipe microbatch pipelining over a mesh axis (DESIGN.md §9).

``gpipe(stage_fn, mesh=m, axis='pod', num_micro=M)`` maps ``n = |axis|``
pipeline stages onto the devices of ``axis``. Stage weights shard over the
axis (device s holds stage s); microbatches stream through with the classic
GPipe schedule: ``M + n − 1`` ticks, tick ``t`` has device ``s`` processing
microbatch ``t − s``, activations hop one device per tick via
``collective_permute`` (nearest-neighbour ICI traffic only — no gather of
the full activation set anywhere). Bubble fraction is the usual
``(n−1)/(M+n−1)``; utilisation is reported by :func:`bubble_fraction` so
launch tooling can size ``num_micro``.

The result is bit-identical to applying the ``n`` stages sequentially to
every microbatch (each microbatch's math is unchanged — only *where* it
runs moves), which is what the dist suite asserts against
:func:`gpipe_reference`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401

tmap = jax.tree_util.tree_map


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    """GPipe idle fraction: (n−1) / (M+n−1)."""
    return (num_stages - 1) / (num_micro + num_stages - 1)


def gpipe_reference(stage_fn: Callable, ws, x: jax.Array) -> jax.Array:
    """Sequential oracle: run every stage over every microbatch in order."""
    n = jax.tree_util.tree_leaves(ws)[0].shape[0]
    for i in range(n):
        w = tmap(lambda l: l[i], ws)
        x = jax.vmap(lambda xm, w=w: stage_fn(w, xm))(x)
    return x


def gpipe(stage_fn: Callable, *, mesh, axis: str, num_micro: int) -> Callable:
    """Build ``f(ws, x)``: the pipelined equivalent of sequentially applying
    ``n = mesh.shape[axis]`` stages to ``num_micro`` microbatches.

    stage_fn(w, x_mb) → y_mb  (same shape/dtype as x_mb — pipeline stages
    must be shape-preserving so activations can hop between devices).
    ws: pytree of stage-stacked weights, every leaf shaped (n, ...).
    x: (num_micro, mb, ...) microbatched input, replicated.
    """
    n = int(mesh.shape[axis])
    ticks = num_micro + n - 1
    shift_right = [(i, i + 1) for i in range(n - 1)]
    cache = {}      # (ws treedef, leaf ndims) → jitted shard_map'd program

    def local(ws_l, x_all):
        idx = jax.lax.axis_index(axis)
        w = tmap(lambda l: l[0], ws_l)           # this device's stage
        carry = jnp.zeros_like(x_all[0])         # activation from s−1
        ys = jnp.zeros_like(x_all)
        for t in range(ticks):                   # static schedule
            feed = x_all[min(t, num_micro - 1)]  # stage-0 intake
            out = stage_fn(w, jnp.where(idx == 0, feed, carry))
            m = t - (n - 1)                      # microbatch leaving
            if 0 <= m < num_micro:
                ys = ys.at[m].set(jnp.where(idx == n - 1, out, ys[m]))
            if t < ticks - 1:
                carry = jax.lax.ppermute(out, axis, shift_right)
        # only the last stage holds results; psum replicates them
        return jax.lax.psum(ys, axis)

    def run(ws, x):
        leaves, treedef = jax.tree_util.tree_flatten(ws)
        key = (treedef, tuple(l.ndim for l in leaves))
        fn = cache.get(key)
        if fn is None:
            w_specs = tmap(lambda l: P(axis, *([None] * (l.ndim - 1))), ws)
            fn = jax.jit(jax.shard_map(local, mesh=mesh,
                                       in_specs=(w_specs, P()),
                                       out_specs=P(), check_vma=False))
            cache[key] = fn                      # repeat calls reuse the jit
        return fn(ws, x)

    return run
