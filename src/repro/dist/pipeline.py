"""Pipeline parallelism over a mesh axis (DESIGN.md §9).

Forward-only GPipe plus full **pipelined training** with 1F1B and GPipe
schedules. ``gpipe(stage_fn, mesh=m, axis='pod', num_micro=M)`` maps
``n = |axis|`` pipeline stages onto the devices of ``axis``. Stage weights
shard over the axis (device s holds stage s); microbatches stream through
with the classic GPipe schedule: ``M + n − 1`` ticks, tick ``t`` has device
``s`` processing microbatch ``t − s``, activations hop one device per tick
via ``collective_permute`` (nearest-neighbour ICI traffic only — no gather
of the full activation set anywhere). Bubble fraction is the usual
``(n−1)/(M+n−1)``; utilisation is reported by :func:`bubble_fraction` so
launch tooling can size ``num_micro``.

Training (:func:`pipeline_train_step`) runs the same lockstep-SPMD style
with a *backward wave* flowing in the opposite direction: activations hop
right (stage s → s+1), cotangents hop left (s+1 → s), both via
``collective_permute``. Two schedules share one implementation, differing
only in when device ``s`` runs the backward of microbatch ``m``:

  1F1B   fwd(m,s) at tick m+s,  bwd(m,s) at tick m + 2n−1−s
  GPipe  fwd(m,s) at tick m+s,  bwd(m,s) at tick m + M+2n−2−s

Under 1F1B device ``s`` holds at most ``min(M, 2(n−s)−1)`` stashed
activations (O(n), independent of M — the memory point of 1F1B; the stash
is a ``min(M, 2n−1)``-deep ring buffer vs GPipe's M). 1F1B also packs the
two waves into ``M+2n−1`` ticks against GPipe training's ``2(M+n−1)``, so
each device sits idle for fewer schedule ticks: see
:func:`bubble_fraction_1f1b`.

Results are numerically identical to sequentially applying the ``n``
stages to every microbatch and calling ``jax.grad`` (the backward pass
recomputes each stage forward from the stashed stage *input* — the same
ops in the same order as the oracle's VJP), which is what the dist suite
asserts against :func:`gpipe_reference` / :func:`pipeline_train_reference`.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401
from repro.dist.collectives import (permute_quantized,
                                    tree_quantized_allreduce)

tmap = jax.tree_util.tree_map


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    """GPipe idle fraction: (n−1) / (M+n−1).

    Holds for forward-only GPipe (M+n−1 ticks, M useful per device) and for
    GPipe *training* as implemented here (a forward sweep then a backward
    sweep, 2(M+n−1) ticks, 2M useful — the ratio is unchanged).
    """
    return (num_stages - 1) / (num_micro + num_stages - 1)


def bubble_fraction_1f1b(num_stages: int, num_micro: int) -> float:
    """1F1B idle-tick fraction of the lockstep schedule: (n−1) / (M+2n−1).

    Accounting: the 1F1B schedule spans ``M+2n−1`` permute-synchronised
    ticks. Device ``s`` has a valid forward on M of them (ticks s..s+M−1)
    and a valid backward on M (ticks 2n−1−s .. 2n−2−s+M); the two ranges
    overlap on ``M−|2n−1−2s|`` ticks, so it sits fully idle on
    ``n−1+...`` ticks — averaged over stages, ``n−1`` of ``M+2n−1``.
    GPipe training spans ``2(M+n−1)`` ticks with *disjoint* forward and
    backward ranges per device, giving the classic ``(n−1)/(M+n−1)`` —
    strictly worse for every M ≥ 1, n ≥ 2. (Total compute emitted is the
    same; 1F1B wins by keeping devices busy on more ticks and by the O(n)
    activation stash.)
    """
    n, m = num_stages, num_micro
    if n <= 1:
        return 0.0
    return (n - 1) / (m + 2 * n - 1)


def gpipe_reference(stage_fn: Callable, ws, x: jax.Array) -> jax.Array:
    """Sequential oracle: run every stage over every microbatch in order."""
    n = jax.tree_util.tree_leaves(ws)[0].shape[0]
    for i in range(n):
        w = tmap(lambda l: l[i], ws)
        x = jax.vmap(lambda xm, w=w: stage_fn(w, xm))(x)
    return x


def gpipe(stage_fn: Callable, *, mesh, axis: str, num_micro: int,
          act_wire: str = "fp32") -> Callable:
    """Build ``f(ws, x)``: the pipelined equivalent of sequentially applying
    ``n = mesh.shape[axis]`` stages to ``num_micro`` microbatches.

    stage_fn(w, x_mb) → y_mb  (same shape/dtype as x_mb — pipeline stages
    must be shape-preserving so activations can hop between devices).
    ws: pytree of stage-stacked weights, every leaf shaped (n, ...).
    x: (num_micro, mb, ...) microbatched input, replicated.
    ``act_wire="int8"`` ships the stage-hop activations as int8 codes +
    f32 scale (``dist.collectives.permute_quantized``) instead of f32;
    ``act_wire="b1"`` ships packed sign bits + one α scale (1 bit/element
    — for sign-dominated stage outputs).
    """
    if act_wire not in ("fp32", "int8", "b1"):
        raise ValueError(f"unknown act_wire {act_wire!r}")
    n = int(mesh.shape[axis])
    ticks = num_micro + n - 1
    shift_right = [(i, i + 1) for i in range(n - 1)]
    cache = {}      # (ws treedef, leaf ndims) → jitted shard_map'd program

    def local(ws_l, x_all):
        idx = jax.lax.axis_index(axis)
        w = tmap(lambda l: l[0], ws_l)           # this device's stage
        carry = jnp.zeros_like(x_all[0])         # activation from s−1
        ys = jnp.zeros_like(x_all)
        for t in range(ticks):                   # static schedule
            feed = x_all[min(t, num_micro - 1)]  # stage-0 intake
            out = stage_fn(w, jnp.where(idx == 0, feed, carry))
            m = t - (n - 1)                      # microbatch leaving
            if 0 <= m < num_micro:
                ys = ys.at[m].set(jnp.where(idx == n - 1, out, ys[m]))
            if t < ticks - 1:
                carry = (jax.lax.ppermute(out, axis, shift_right)
                         if act_wire == "fp32" else
                         permute_quantized(out, axis, shift_right,
                                           wire=act_wire))
        # only the last stage holds results; psum replicates them
        return jax.lax.psum(ys, axis)

    def run(ws, x):
        leaves, treedef = jax.tree_util.tree_flatten(ws)
        key = (treedef, tuple(l.ndim for l in leaves))
        fn = cache.get(key)
        if fn is None:
            w_specs = tmap(lambda l: P(axis, *([None] * (l.ndim - 1))), ws)
            fn = jax.jit(jax.shard_map(local, mesh=mesh,
                                       in_specs=(w_specs, P()),
                                       out_specs=P(), check_vma=False))
            cache[key] = fn                      # repeat calls reuse the jit
        return fn(ws, x)

    return run


# ---------------------------------------------------------------------------
# Pipelined training (1F1B / GPipe schedules) — DESIGN.md §9
# ---------------------------------------------------------------------------

def _schedule_constants(num_stages: int, num_micro: int,
                        schedule: str) -> dict:
    """Static tick table. fwd(m,s) runs at tick m+s under both schedules;
    bwd(m,s) at tick m + base − s. Validity is masked per device; whole
    phases with no valid work anywhere are statically elided via the
    lo/hi ranges. ``ring`` is the activation-stash depth."""
    n, m = num_stages, num_micro
    if schedule == "1f1b":
        return {"ticks": m + 2 * n - 1, "ring": min(m, 2 * n - 1),
                "base": 2 * n - 1, "bwd_lo": n, "bwd_hi": m + 2 * n - 2,
                "fwd_hi": m + n - 2}
    if schedule == "gpipe":
        return {"ticks": 2 * (m + n - 1), "ring": m,
                "base": m + 2 * n - 2, "bwd_lo": m + n - 1,
                "bwd_hi": 2 * m + 2 * n - 3, "fwd_hi": m + n - 2}
    raise ValueError(f"unknown pipeline schedule {schedule!r}")


def pipeline_train_local(stage_fn: Callable, loss_fn: Callable, *,
                         axis: str, num_stages: int, num_micro: int,
                         schedule: str = "1f1b",
                         act_wire: str = "fp32") -> Callable:
    """Per-device pipelined fwd+bwd, for use *inside* a ``shard_map``.

    Returns ``local(ws_l, top, x_all, aux) → (loss, dw, dtop, dx)`` where
    ``ws_l`` is this device's stage-weight slice (leaves ``(1, ...)``),
    ``top`` a replicated pytree consumed by the loss (LM head / final norm;
    ``{}`` if unused), ``x_all`` the ``(M, mb, ...)`` microbatched input and
    ``aux`` a pytree of per-microbatch loss inputs with leading dim M
    (``{}`` if unused). ``loss_fn(top, y_mb, aux_mb) → scalar``.

    Outputs are device-local: ``dw`` is the grad of this device's stage,
    ``loss``/``dtop`` are nonzero only on the last stage and ``dx`` (the
    cotangent of ``x_all``) only on stage 0 — callers psum them over
    ``axis``. All grads are for the *mean* loss over microbatches.

    The backward recomputes each stage's forward from the stashed stage
    input (rather than stashing VJP residuals), so the stash is one
    activation per in-flight microbatch — a ``min(M, 2n−1)`` ring under
    1F1B — and the math is op-for-op the oracle's VJP.
    """
    n, num_m = num_stages, num_micro
    if act_wire not in ("fp32", "int8", "b1"):
        raise ValueError(f"unknown act_wire {act_wire!r}")
    sc = _schedule_constants(n, num_m, schedule)
    # the b1 wire applies to the rightward *activation* wave only: stage
    # outputs can be sign-dominated (saturated nonlinearities), cotangents
    # never are — the leftward wave degrades to the int8 wire instead of
    # losing its magnitudes entirely.
    fwd_wire = act_wire
    bwd_wire = "int8" if act_wire == "b1" else act_wire

    def hop(x, perm, wire):
        # the stage-boundary wire: both the rightward activation wave and
        # the leftward cotangent wave cross it (quantized codes + f32
        # scale when the wire is int8/b1 — ≤1 byte/elem of ICI, like
        # every other boundary in the W1A8 dataflow)
        if wire == "fp32":
            return jax.lax.ppermute(x, axis, perm)
        return permute_quantized(x, axis, perm, wire=wire)
    shift_right = [(i, i + 1) for i in range(n - 1)]
    shift_left = [(i + 1, i) for i in range(n - 1)]

    def local(ws_l, top, x_all, aux):
        idx = jax.lax.axis_index(axis)
        first, last = idx == 0, idx == n - 1
        w = tmap(lambda l: l[0], ws_l)
        mb_shape = x_all.shape[1:]
        carry = jnp.zeros(mb_shape, x_all.dtype)    # activation from s−1
        ct_in = jnp.zeros(mb_shape, x_all.dtype)    # cotangent from s+1
        stash = jnp.zeros((sc["ring"],) + mb_shape, x_all.dtype)
        gw = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), w)
        gtop = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), top)
        dxs = jnp.zeros_like(x_all)
        loss_acc = jnp.zeros((), jnp.float32)

        for t in range(sc["ticks"]):                # static schedule
            # backward half-tick runs first: when the ring is at capacity
            # the forward half of the same tick reuses the slot read here
            if sc["bwd_lo"] <= t <= sc["bwd_hi"]:
                m_b = t - (sc["base"] - idx)
                valid = (m_b >= 0) & (m_b < num_m)
                m_c = jnp.clip(m_b, 0, num_m - 1)
                x_saved = jax.lax.dynamic_index_in_dim(
                    stash, jnp.mod(m_c, sc["ring"]), 0, keepdims=False)
                aux_m = tmap(lambda a: jax.lax.dynamic_index_in_dim(
                    a, m_c, 0, keepdims=False), aux)
                y, f_stage = jax.vjp(stage_fn, w, x_saved)

                def head(y_, aux_m=aux_m):
                    return jax.value_and_grad(
                        lambda tp, yy: loss_fn(tp, yy, aux_m),
                        argnums=(0, 1))(top, y_)

                # only the last stage owns the loss head: cond (on the
                # per-device predicate) skips the head fwd+bwd — e.g. the
                # vocab-sized unembed — on the other n−1 stages entirely
                head_sds = jax.eval_shape(head, y)
                zeros = tmap(lambda s: jnp.zeros(s.shape, s.dtype),
                             head_sds)
                loss_m, (dtop_m, ct_last) = jax.lax.cond(
                    last, head, lambda y_: zeros, y)
                dw_m, dx_m = f_stage(jnp.where(last, ct_last, ct_in))
                gw = tmap(lambda a, g: a + jnp.where(valid, g, 0.0),
                          gw, dw_m)
                gtop = tmap(lambda a, g: a + jnp.where(valid & last, g, 0.0),
                            gtop, dtop_m)
                loss_acc = loss_acc + jnp.where(valid & last, loss_m, 0.0)
                prev = jax.lax.dynamic_index_in_dim(dxs, m_c, 0,
                                                    keepdims=False)
                dxs = jax.lax.dynamic_update_index_in_dim(
                    dxs, jnp.where(valid & first, dx_m, prev), m_c, 0)
                if t < sc["bwd_hi"]:
                    ct_in = hop(dx_m, shift_left, bwd_wire)
            if t <= sc["fwd_hi"]:
                m_f = t - idx
                valid = (m_f >= 0) & (m_f < num_m)
                x_in = jnp.where(first, x_all[min(t, num_m - 1)], carry)
                out = stage_fn(w, x_in)
                slot = jnp.mod(jnp.clip(m_f, 0, num_m - 1), sc["ring"])
                prev = jax.lax.dynamic_index_in_dim(stash, slot, 0,
                                                    keepdims=False)
                stash = jax.lax.dynamic_update_index_in_dim(
                    stash, jnp.where(valid, x_in, prev), slot, 0)
                if t < sc["fwd_hi"]:
                    carry = hop(out, shift_right, fwd_wire)

        inv = 1.0 / num_m                           # grads of the MEAN loss
        gw = tmap(lambda g, p: (g * inv).astype(p.dtype), gw, w)
        gtop = tmap(lambda g, p: (g * inv).astype(p.dtype), gtop, top)
        return loss_acc * inv, gw, gtop, dxs * inv

    return local


def reduce_pipeline_outputs(loss, gw, gtop, dxs, *, axis: str,
                            dp_axis: Optional[str] = None,
                            grad_wire: str = "fp32"):
    """Shared post-processing for :func:`pipeline_train_local` outputs,
    inside the enclosing shard_map: replicate the stage-local pieces over
    the pipeline ``axis`` (last stage holds loss/dtop, stage 0 holds dx),
    then reduce grads/loss across ``dp_axis`` — over the int8 wire
    (``dist.collectives``) when ``grad_wire == 'int8'``, else an exact
    pmean. ``dxs`` stays batch-sharded, rescaled to be the cotangent of
    the dp-mean loss."""
    loss = jax.lax.psum(loss, axis)
    gtop = tmap(lambda g: jax.lax.psum(g, axis), gtop)
    dxs = jax.lax.psum(dxs, axis)
    if dp_axis is not None:
        if grad_wire == "int8":
            gw = tree_quantized_allreduce(gw, dp_axis)
            gtop = tree_quantized_allreduce(gtop, dp_axis)
        else:
            gw = tmap(lambda g: jax.lax.pmean(g, dp_axis), gw)
            gtop = tmap(lambda g: jax.lax.pmean(g, dp_axis), gtop)
        loss = jax.lax.pmean(loss, dp_axis)
        dxs = dxs / jax.lax.axis_size(dp_axis)
    return loss, gw, gtop, dxs


def pipeline_train_step(stage_fn: Callable, loss_fn: Callable, *, mesh,
                        axis: str, num_micro: int, schedule: str = "1f1b",
                        dp_axis: Optional[str] = None,
                        grad_wire: str = "fp32",
                        act_wire: str = "fp32") -> Callable:
    """Build ``f(ws, x, aux=None, top=None)``: pipelined training over
    ``n = mesh.shape[axis]`` stages, numerically matching the sequential
    :func:`pipeline_train_reference` oracle.

    ws: stage-stacked weights, every leaf ``(n, ...)`` (sharded over axis).
    x: ``(num_micro, mb, ...)`` microbatched input; with ``dp_axis`` the mb
    dim additionally shards over the data axis and grads/loss reduce across
    it — over the int8 wire (``dist.collectives``) when
    ``grad_wire == 'int8'``, else an exact ``pmean``.
    loss_fn(top, y_mb, aux_mb) → scalar mean-reduced per microbatch.
    ``act_wire == 'int8'`` additionally carries the stage-boundary
    ``collective_permute`` payloads — forward activations *and* backward
    cotangents — as int8 codes + f32 scale (4× less ICI per hop; adds the
    per-hop quantization noise the dist tests bound). ``act_wire == 'b1'``
    carries the *forward* activations as packed sign bits + one α scale
    (1 bit/element, ~8× less than int8 on the code payload) while the
    backward cotangents stay on the int8 wire — sign-dominated stage
    outputs keep their information, cotangents keep their magnitudes. The
    loss/grad envelope vs the fp32 wire is documented in EXPERIMENTS.md
    and asserted by tests/test_pipeline_unit.py; it is tight only when
    stage outputs saturate (|out| ≈ const), the b1 contract.

    Returns ``(loss, grads)``; with ``top`` given, ``(loss, grads,
    grads_top, dx)`` where ``dx`` is the cotangent of ``x`` (so callers can
    continue the backward into an embedding front-end).
    """
    if grad_wire not in ("fp32", "int8"):
        raise ValueError(f"unknown grad_wire {grad_wire!r}")
    n = int(mesh.shape[axis])
    local = pipeline_train_local(stage_fn, loss_fn, axis=axis, num_stages=n,
                                 num_micro=num_micro, schedule=schedule,
                                 act_wire=act_wire)
    cache = {}

    def run(ws, x, aux=None, top=None):
        has_top = top is not None
        top_in = {} if top is None else top
        aux_in = {} if aux is None else aux
        leaves, treedef = jax.tree_util.tree_flatten((ws, top_in, aux_in))
        key = (treedef, tuple(l.ndim for l in leaves), x.ndim)
        fn = cache.get(key)
        if fn is None:
            w_specs = tmap(lambda l: P(axis, *([None] * (l.ndim - 1))), ws)
            t_specs = tmap(lambda l: P(), top_in)
            x_spec = P(None, dp_axis) if dp_axis else P()
            a_specs = tmap(lambda l: x_spec, aux_in)

            def prog(ws_l, top_l, x_l, aux_l):
                out = local(ws_l, top_l, x_l, aux_l)
                loss, gw, gtop, dxs = reduce_pipeline_outputs(
                    *out, axis=axis, dp_axis=dp_axis, grad_wire=grad_wire)
                return loss, tmap(lambda g: g[None], gw), gtop, dxs

            fn = jax.jit(jax.shard_map(
                prog, mesh=mesh,
                in_specs=(w_specs, t_specs, x_spec, a_specs),
                out_specs=(P(), w_specs, t_specs, x_spec),
                check_vma=False))
            cache[key] = fn
        loss, gws, gtop, dxs = fn(ws, top_in, x, aux_in)
        if has_top:
            return loss, gws, gtop, dxs
        return loss, gws

    return run


def pipeline_train_reference(stage_fn: Callable, loss_fn: Callable, ws, x,
                             aux=None, top=None):
    """Sequential ``jax.grad`` oracle for :func:`pipeline_train_step`:
    apply every stage to every microbatch in order, mean the losses,
    differentiate. Returns ``(loss, grads)`` — plus ``(grads_top, dx)``
    when ``top`` is given — with the same conventions as the pipelined
    version."""
    has_top = top is not None
    top_in = {} if top is None else top
    aux_in = {} if aux is None else aux
    n = jax.tree_util.tree_leaves(ws)[0].shape[0]
    num_m = x.shape[0]

    def total(ws_, top_, x_):
        losses = []
        for m in range(num_m):
            h = x_[m]
            for i in range(n):
                h = stage_fn(tmap(lambda l: l[i], ws_), h)
            losses.append(loss_fn(top_, h,
                                  tmap(lambda a: a[m], aux_in)))
        return jnp.mean(jnp.stack(losses))

    loss, (gws, gtop, dx) = jax.value_and_grad(
        total, argnums=(0, 1, 2))(ws, top_in, x)
    if has_top:
        return loss, gws, gtop, dx
    return loss, gws
