"""Quantized collectives: int8-on-the-wire gradient all-reduce (DESIGN.md §9).

The paper's W1A8 wire discipline — carry codes, not floats, and keep the
scale arithmetic exact on the side — applied to the data-parallel gradient
reduction. A mean all-reduce over ``n`` shards decomposes into

    quantize → all_to_all(int8 codes) → local sum (int32) →
    requantize → all_gather(int8 codes) → dequantize

i.e. a reduce-scatter + all-gather ring where **every inter-chip payload is
1 byte/element**: ≈4× less ICI traffic than an f32 ring all-reduce (2×4
bytes·(n−1)/n vs 2×1). Both quantization stages share one per-leaf scale
across shards (``pmax`` of the abs-max, scalar-sized), so codes from
different shards are summable exactly in int32 — the same
compensation-survives-parallelism rule as the sharding layer.

Precision: symmetric int8 with round-half-away (``core.quant``) carries
~0.23%·max quantization noise per stage; on unit-normal gradients the two
stages compose to ≈1% relative error on the mean — the bandwidth/precision
trade the dist tests assert (<3%).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401
from repro.core.qtensor import QTensor
from repro.core.quant import round_half_away

tmap = jax.tree_util.tree_map

_QMAX = 127  # symmetric int8 code range [-127, 127]


def _quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(round_half_away(x / scale), -_QMAX, _QMAX).astype(jnp.int8)


def _shared_scale(x: jax.Array, axis: str) -> jax.Array:
    """One scale for all shards: pmax of the local abs-max (scalar wire)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
    return jnp.maximum(amax, 1e-20) / _QMAX


def quantized_allreduce_mean(g: jax.Array, axis: str) -> jax.Array:
    """Mean of ``g`` across ``axis`` with int8 payloads (inside shard_map).

    Non-float leaves (step counters riding in the tree) fall back to an
    exact dtype-preserving mean: psum then floor-div — identical replicated
    values come back unchanged.
    """
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return jax.lax.psum(g, axis) // jax.lax.axis_size(axis)
    n = jax.lax.axis_size(axis)
    shape, dtype = g.shape, g.dtype
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)                       # row j → shard j

    # reduce-scatter leg: int8 codes, exchanged with all_to_all
    scale1 = _shared_scale(chunks, axis)
    codes = jax.lax.all_to_all(_quantize(chunks, scale1), axis,
                               split_axis=0, concat_axis=0)
    # local accumulation is exact: |sum| ≤ n·127 ≪ int32
    part = jnp.sum(codes.astype(jnp.int32), axis=0).astype(jnp.float32) \
        * scale1 / n                                   # this shard's mean

    # all-gather leg: requantized int8 codes of the mean chunk
    scale2 = _shared_scale(part, axis)
    gathered = jax.lax.all_gather(_quantize(part, scale2), axis, tiled=True)
    out = gathered.astype(jnp.float32) * scale2
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


def tree_quantized_allreduce(tree, axis: str):
    """Per-leaf-scaled int8 mean all-reduce over a gradient pytree."""
    return tmap(lambda g: quantized_allreduce_mean(g, axis), tree)


def wire_bytes_saved(tree, n: int) -> dict:
    """Accounting helper: int8 ring traffic vs f32 ring all-reduce."""
    numel = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(tree))
    f = (n - 1) / max(n, 1)
    f32 = 2 * 4 * numel * f
    int8 = 2 * 1 * numel * f
    return {"f32_bytes": f32, "int8_bytes": int8,
            "ratio": f32 / max(int8, 1)}


# ---------------------------------------------------------------------------
# Point-to-point int8 wire: the pipeline-stage collective_permute payload.
# ---------------------------------------------------------------------------

def quantize_wire(x: jax.Array, qtype: str = "s8") -> QTensor:
    """f32 → QTensor wire payload with a *local* per-tensor scale.

    Unlike the all-reduce legs there is no cross-shard sum here — each
    stage-to-stage hop carries exactly one tensor from one sender — so no
    pmax'd shared scale is needed: the 4-byte scale rides the wire next to
    its codes (the QTensor's two pytree leaves are the wire format).

    ``qtype="s8"`` — symmetric int8, 1 byte/element (`QTensor.quantize_s8`).
    ``qtype="b1"`` — packed sign bits + α = mean|x|, 1 *bit*/element
    (`QTensor.quantize_b1`, packed along the trailing axis): the wire for
    sign-dominated boundaries, where magnitude is saturated and the sign
    plane carries the information.
    """
    if qtype == "s8":
        return QTensor.quantize_s8(x)
    if qtype == "b1":
        return QTensor.quantize_b1(x)
    raise ValueError(f"unknown wire qtype {qtype!r}")


def dequantize_wire(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    return qt.dequantize().astype(dtype)


_WIRE_QTYPES = {"int8": "s8", "b1": "b1"}


def permute_quantized(x: jax.Array, axis: str, perm,
                      wire: str = "int8") -> jax.Array:
    """``ppermute`` with quantized codes + f32 scale on the wire, not f32.

    quantize → permute the QTensor (a pytree: both leaves hop together) →
    dequantize on the receiver. Devices outside ``perm`` receive zeros for
    both leaves, so they dequantize to exactly 0 — identical boundary
    semantics to a plain f32 ppermute (for ``wire="b1"`` the zero words
    unpack to −1 signs, but the zero scale still yields exact 0).

    Error envelopes: ``wire="int8"`` — symmetric int8 round-half-away ⇒
    |x̂ − x| ≤ scale/2 = max|x|/254 per element (~0.4%·max per hop), the
    bound the dist tests assert. ``wire="b1"`` — x̂ = sign(x)·mean|x|:
    magnitude information is gone entirely, so the per-element error is
    |x| − α-sized; tight only on sign-dominated tensors (|x| ≈ const),
    which is the contract `pipeline_train_step(act_wire="b1")` documents.
    """
    qt = jax.lax.ppermute(quantize_wire(x, _WIRE_QTYPES[wire]), axis, perm)
    return dequantize_wire(qt, x.dtype)


def permute_wire_bytes(x: jax.Array, n_hops: int) -> dict:
    """Accounting: per-schedule-tick permute payload — f32 vs int8 vs b1.

    int8: 1 byte/element + one 4-byte scale per hop. b1: the trailing
    axis packs 32 signs/uint32 word (padded to a word boundary) + one
    4-byte α per hop — the code payload is exactly 8× smaller than
    int8's (1 bit vs 8), the end-to-end hop ratio approaches 8× from
    below because both wires carry the same 4-byte scale.
    """
    numel = int(jnp.size(x))
    last = int(x.shape[-1]) if jnp.ndim(x) else 1
    words = (numel // max(last, 1)) * ((last + 31) // 32)
    f32 = 4 * numel * n_hops
    int8 = (1 * numel + 4) * n_hops
    b1 = (4 * words + 4) * n_hops
    return {"f32_bytes": f32, "int8_bytes": int8, "b1_bytes": b1,
            "ratio": f32 / max(int8, 1),
            "ratio_f32_b1": f32 / max(b1, 1),
            "ratio_int8_b1": int8 / max(b1, 1),
            "ratio_int8_b1_codes": numel / max(4 * words, 1)}
