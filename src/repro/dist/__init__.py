"""Distribution layer: sharding rules, quantized collectives, pipelining.

The scale-out counterpart of the paper's streaming W1A8 dataflow (DESIGN.md
§9): the same compensation/scale split that survives the mapping to the
binary PE must survive the mapping to a pod —

  * ``sharding``    — PartitionSpec rules for every param leaf of every arch
                      (model axis on attention/FFN projections, (data, model)
                      on MoE expert stacks),
  * ``collectives`` — int8-on-the-wire gradient all-reduce with per-leaf
                      scales (the W1A8 wire format applied to collectives),
  * ``pipeline``    — GPipe microbatch pipelining over a mesh axis.
"""
from repro import compat  # noqa: F401  (installs the jax.shard_map shim)
from repro.dist import collectives, pipeline, sharding  # noqa: F401
