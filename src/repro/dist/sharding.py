"""Sharding rules: param path + shape → PartitionSpec (DESIGN.md §9).

One function, ``param_spec``, maps every parameter leaf of every arch in
``configs.ARCH_NAMES`` (and the optimizer/packed-deploy trees derived from
them) to a legal ``PartitionSpec`` on a ('data', 'model') — or
('pod', 'data', 'model') — mesh:

  * attention / dense-FFN / SSM projections: **tensor-parallel** over
    ``model`` — column-parallel (wq/wk/wv/up/gate/in_proj: output dim),
    row-parallel (wo/down/out_proj: contraction dim). Bit-packed deploy
    weights (``w_packed``) shard the same dims (the /32 word dim stands in
    for K), so the W1A8 scale split (alpha per output channel, act_step per
    tensor) is preserved shard-locally — the REQ-YOLO/FracBNN lesson that
    compensation arithmetic must survive the parallel mapping.
  * MoE expert stacks (E, K, N): **expert-parallel** over ``data`` on E and
    tensor-parallel over ``model`` inside the expert (up/gate: hidden F
    columns; down: hidden F rows) — matching the shard_map specs used by
    ``models.transformer._apply_moe``.
  * embedding / LM head: vocab-sharded over ``model`` (the z-loss softmax
    partitions cleanly).
  * norms, biases of row-parallel projections, scalar LSQ steps, router:
    replicated.

An axis is only placed when the dim is divisible by the mesh axis size, so
every spec is legal for every (arch × mesh) cell; optimizer trees (adamw
mu/nu mirror params; adafactor vr/vc are reduced) inherit rules by path and
keep whatever placements still divide.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat  # noqa: F401

# leaf names of column-parallel projections (shard output dim over model)
_COL_PARALLEL = ("wq", "wk", "wv", "up", "gate", "in_proj", "x_proj",
                 "dt_proj", "shared_up", "shared_gate")
# leaf names of row-parallel projections (shard contraction dim over model)
_ROW_PARALLEL = ("wo", "down", "out_proj", "shared_down")

_KEY_RE = re.compile(r"\['([^']+)'\]")


def dp_axes(mesh) -> tuple:
    """Mesh axes the batch shards over (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axsize(mesh, axis: str) -> int:
    return int(mesh.shape[axis])


def _fits(mesh, shape, dim: int, axis: str) -> bool:
    """True iff `axis` exists and divides shape[dim] (dim may be negative)."""
    if axis not in mesh.axis_names:
        return False
    if not (-len(shape) <= dim < len(shape)):
        return False
    return shape[dim] % _axsize(mesh, axis) == 0


def _spec(ndim: int, placements: dict) -> P:
    """Build a PartitionSpec from {dim (may be negative): axis}."""
    entries = [None] * ndim
    for dim, axis in placements.items():
        entries[dim % ndim] = axis
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _moe_spec(keys, shape, mesh) -> P:
    """Expert stacks: leaves under a ['moe'] node (or a bare MoE param dict).

    Canonical shapes (an optional leading stage dim rides along replicated):
      up/gate[_packed]   (E, K[/32], F)   → ep on E, model on F (columns)
      down[_packed]      (E, F[/32], D)   → ep on E, model on F (rows)
      up/gate_alpha      (E, 1, F)        → ep on E, model on F
      down_alpha         (E, 1, D)        → ep on E
      router (D, E), act_step (), shared_* (dense rules) → see param_spec
    """
    leaf = keys[-1]
    ndim = len(shape)
    placements = {}
    # E is third-from-last for the 3D+ expert stacks; for reduced optimizer
    # leaves (adafactor vr/vc drop a trailing dim) fall back to dim 0.
    e_dim = (-3 if ndim >= 3 else 0) % ndim
    if _fits(mesh, shape, e_dim, "data"):
        placements[e_dim] = "data"
    if leaf.startswith(("up", "gate")):
        tp_dim = (-1) % ndim
    elif leaf.startswith("down") and not leaf.endswith("alpha") and ndim >= 2:
        tp_dim = (-2) % ndim
    else:
        tp_dim = None
    if tp_dim is not None and tp_dim != e_dim \
            and _fits(mesh, shape, tp_dim, "model"):
        placements[tp_dim] = "model"
    return _spec(ndim, placements)


def param_spec(path: str, shape, cfg, mesh) -> P:
    """PartitionSpec for one param leaf.

    path: ``jax.tree_util.keystr``-style string, e.g.
    ``"['slots'][0]['attn']['wq']['w']"`` (optimizer prefixes like ['mu']
    are ignored — rules match on the innermost module keys).
    shape: the leaf's shape (with or without the stacked stage dim).
    """
    keys = _KEY_RE.findall(path)
    ndim = len(shape)
    if ndim == 0 or not keys:
        return P()

    # ---- MoE expert tensors: (data, model) ---------------------------------
    if "moe" in keys:
        leaf = keys[-1]
        if leaf == "router" or leaf == "act_step":
            return P()
        if leaf.startswith("shared_"):
            dim = -1 if leaf in ("shared_up", "shared_gate") else -2
            if _fits(mesh, shape, dim, "model") and ndim >= 2:
                return _spec(ndim, {dim: "model"})
            return P()
        return _moe_spec(keys, shape, mesh)

    # ---- embedding / LM head: vocab over model -----------------------------
    if keys[-1] == "emb":
        if ndim >= 2 and _fits(mesh, shape, -2, "model"):
            return _spec(ndim, {-2: "model"})
        return P()
    if keys[-1] == "head":
        if _fits(mesh, shape, -1, "model"):
            return _spec(ndim, {-1: "model"})
        return P()

    # ---- projections (attn / dense mlp / mamba), incl. packed deploy -------
    proj = next((k for k in reversed(keys) if k in _COL_PARALLEL
                 or k in _ROW_PARALLEL), None)
    if proj is not None:
        leaf = keys[-1]
        col = proj in _COL_PARALLEL
        if leaf in ("w", "w_packed", "vr", "vc", "v", proj):
            # weight matrix (…, K[/32], N) or a same-/reduced-shape moment
            if col and _fits(mesh, shape, -1, "model"):
                return _spec(ndim, {-1: "model"})
            if not col and ndim >= 2 and _fits(mesh, shape, -2, "model"):
                return _spec(ndim, {-2: "model"})
            return P()
        if leaf in ("b", "alpha") and col and _fits(mesh, shape, -1, "model"):
            # output-channel vectors follow the column shards
            return _spec(ndim, {-1: "model"})
        return P()

    # ---- depthwise conv / SSM channel vectors ------------------------------
    if keys[-1] in ("conv_w", "conv_b") and _fits(mesh, shape, -1, "model"):
        return _spec(ndim, {-1: "model"})

    # norms, scalar steps, A_log/D/dt_bias, step counters: replicate
    return P()


def tree_shardings(tree, cfg, mesh):
    """Map every leaf of a param/optimizer/cache-free tree to a
    ``NamedSharding`` built from :func:`param_spec`.

    Accepts concrete arrays or ``ShapeDtypeStruct`` leaves (eval_shape
    trees); returns a tree of identical structure.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(mesh, param_spec(jax.tree_util.keystr(p),
                                          leaf.shape, cfg, mesh))
           for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def pipeline_tree_shardings(tree, mesh, num_layers: int,
                            axis: str = "stage"):
    """Placement for pipelined training (``launch/train.py --pipeline``):
    every layer-stacked leaf (leading dim == num_layers, which the stage
    partition later reshapes to ``(n, L/n, ...)``) shards over the pipeline
    ``axis`` — so each device's params *and optimizer state* live on their
    stage shard — and everything else (embed, final norm, step counters)
    replicates. Applies to params and any optimizer tree derived from them
    (adamw mu/nu mirror shapes; adafactor vr/vc keep the leading L)."""
    n = _axsize(mesh, axis)

    def one(leaf):
        shape = leaf.shape
        if len(shape) >= 1 and shape[0] == num_layers \
                and num_layers % n == 0:
            return NamedSharding(mesh, _spec(len(shape), {0: axis}))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, tree)


def spec_report(tree, cfg, mesh, *, only_sharded: bool = False) -> str:
    """Human-readable leaf → spec table (debugging / DESIGN.md audits)."""
    lines = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        spec = param_spec(jax.tree_util.keystr(p), leaf.shape, cfg, mesh)
        if only_sharded and all(s is None for s in spec):
            continue
        lines.append(f"{jax.tree_util.keystr(p):70s} {str(leaf.shape):24s} "
                     f"{spec}")
    return "\n".join(lines)
