"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H GQA(kv=8) ff24576 v65536,
Mamba-1(state 16) : attention 7:1 interleave, MoE 16e top-2 every other
layer — ≈398B total params. [arXiv:2403.19887; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    num_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_kind="mamba1", attn_every=8,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=128, num_experts=4, top_k=2,
        ssm_state=8, capacity_factor=4.0)
