"""seamless-m4t-medium [audio enc-dec]: 12L d1024 16H (MHA) ff4096 v256206.

Backbone only — the audio frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S, 1024). RoPE replaces the original
relative positions (TPU adaptation note, DESIGN.md §8).
[arXiv:2308.11596; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12, frontend="audio",
    norm_kind="layer", act_fn="gelu", gated_mlp=False,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128)
