"""Assigned input-shape sets (LM family): 4 shapes × 10 archs = 40 cells.

``train_*``  lowers train_step;  ``prefill_*`` lowers a forward pass;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV/SSM
cache of the given length). long_500k runs only for architectures with
bounded-state decode (SSM / hybrid / SWA) — skips recorded per cell.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Architectures whose decode state stays bounded at 500k context:
# SSM (mamba2), hybrid (jamba), sliding-window (mixtral, window 4096).
LONG_OK = {"mamba2-1.3b", "jamba-1.5-large-398b", "mixtral-8x7b"}


def applicable_shapes(arch_name: str) -> list:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and arch_name not in LONG_OK:
            continue
        out.append(s)
    return out


def skip_reason(arch_name: str, shape_name: str) -> str:
    if shape_name == "long_500k" and arch_name not in LONG_OK:
        return ("pure full-attention architecture: 500k global-attention "
                "decode has unbounded KV state (DESIGN.md §5)")
    return ""
