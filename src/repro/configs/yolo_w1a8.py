"""The paper's own model: W1A8 YOLOv3-tiny-like detector (Table 1).

320×320×3 → 10×10×75; Conv1/Conv11 fixed-point standard conv, Conv2–10
W1A8. Structure lives in repro.models.yolo (YOLO_LAYERS); this config file
exists so ``--arch yolo-w1a8`` is selectable next to the LM archs.
"""
from repro.models.yolo import (GRID, INPUT_SIZE,  # noqa: F401
                               NUM_ANCHORS, NUM_CLASSES, YOLO_LAYERS,
                               count_gflops, count_params)

NAME = "yolo-w1a8"
LAYERS = YOLO_LAYERS
