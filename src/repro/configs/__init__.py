"""Config registry: 10 assigned architectures + the paper's own detector.

``get_config(name)`` / ``get_reduced(name)`` resolve by the public dashed id
(e.g. ``--arch mixtral-8x7b``). ``ARCH_NAMES`` lists the LM-family archs in
assignment order; the paper's detector is ``yolo-w1a8`` (see
repro.configs.yolo_w1a8).
"""
from __future__ import annotations

import importlib

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "gemma2-27b": "gemma2_27b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "granite-20b": "granite_20b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-76b": "internvl2_76b",
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).reduced()
