"""granite-20b [dense/code]: 52L d6144 48H MQA(kv=1) ff24576 v49152,
non-gated GELU MLP (gpt-bigcode lineage). [arXiv:2405.04324; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    act_fn="gelu", gated_mlp=False,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128)
