"""mamba2-1.3b [ssm]: 48L d2048, attention-free SSD (state-space duality),
d_state 128, expand 2, headdim 64, v50280 — O(1)-state decode, runs
long_500k. [arXiv:2405.21060; unverified]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_kind="mamba2", ssm_expand=2, ssm_headdim=64,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=128, ssm_state=16,
        ssm_headdim=16)
