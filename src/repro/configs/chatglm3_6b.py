"""chatglm3-6b [dense]: 28L d4096 32H GQA(kv=2) ff13696 v65024,
2D RoPE (rotary on half the head dim). [arXiv:2406.12793; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rope_fraction=0.5,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128)
