"""internvl2-76b [vlm]: 80L d8192 64H GQA(kv=8) ff28672 v128256
(InternLM2-based LM backbone). The InternViT frontend is a stub:
input_specs() provides 256 precomputed patch embeddings per image.
[arXiv:2404.16821; unverified]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    frontend="vision", prefix_len=256,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128, prefix_len=4)
