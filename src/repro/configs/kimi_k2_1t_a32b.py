"""kimi-k2-1t-a32b [moe]: 61L d7168 64H GQA(kv=8) per-expert ff2048
v163840, 384 routed experts top-8 + 1 shared — ~1.04T params, ~32B active.

1-bit expert weights (W1A8) pack the 1T to ~134 GB — the headline capacity
result (DESIGN.md §5). [arXiv:2501.kimi2; unverified]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=128,
    num_experts=384, top_k=8, shared_experts=1,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=128, num_experts=8, top_k=2, capacity_factor=8.0)
