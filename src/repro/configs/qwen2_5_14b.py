"""qwen2.5-14b [dense]: 48L d5120 40H GQA(kv=8) ff13824 v152064, QKV bias.
[hf:Qwen/Qwen2.5-0.5B scaled family config; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, head_dim=128,
    qkv_bias=True,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128)
