"""gemma2-27b [dense]: 46L d4608 32H GQA(kv=16) ff36864 v256000,
alternating local(SWA-4096)/global attention, logit softcaps (50 attn /
30 final), post-norms. [arXiv:2408.00118; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    act_fn="gelu",
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128, sliding_window=8)
