"""mixtral-8x7b [moe]: 32L d4096 32H GQA(kv=8) ff14336 v32000,
8 experts top-2, sliding-window attention 4096. [arXiv:2401.04088; hf]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, top_k=2, sliding_window=4096,
    w1a8_body=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=128, num_experts=4, top_k=2,
        sliding_window=8, capacity_factor=4.0)
