"""KernelConfig — one frozen launch-config object for the W1A8 kernels.

Collapses the per-call kwargs that used to be scattered over
``w1a8_matmul`` / ``w1a8_conv3x3`` / ``w1a8_conv3x3_pool`` (``accum``,
``out_step``, ``interpret``, ``use_kernel``, implicit tile picks) into one
hashable dataclass that jit treats as a static argument, plus the
resolution machinery that turns an (op, layer shape, accum, device) cell
into a concrete config:

    exact autotune-table hit  →  nearest-shape fallback  →  heuristics

The committed table lives at ``benchmarks/results/AUTOTUNE_kernels.json``
(``REPRO_AUTOTUNE_TABLE`` overrides; produced by ``repro.launch.autotune``).
Every table winner is bit-exact vs the heuristic default by construction —
tile/row blocking never changes the per-row dot operands, only the launch
grid — so resolution is a pure perf decision (DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import warnings
from typing import Dict, Optional, Sequence, Tuple

OPS = ("matmul", "conv3x3", "conv3x3_pool")
ACCUMS = ("dot", "popcount")

# Heuristic tile preferences (the former ops.py `_pick` constants).
DEF_BM, DEF_BK, DEF_BN = 256, 512, 256
PACK = 32  # mirrors core.packing.PACK without importing jax at module load


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_tile(dim: int, pref: int, mult: int) -> int:
    """Largest tile ≤ pref that keeps padding small; multiple of `mult`."""
    if dim >= pref:
        return pref
    return max(mult, _round_up(dim, mult))


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Launch configuration for one W1A8 kernel call.

    Frozen + hashable ⇒ usable directly as a jit static argument; two
    configs that launch identically compare/hash equal (``source`` is
    provenance only and excluded from eq/hash).

    ``interpret=None`` resolves at call time to "am I off-TPU?" —
    ``True`` on the CPU backend, ``False`` otherwise. ``bm/bn/bk=None``
    fall back to the `pick_tile` heuristics at the call site. ``rows`` is
    the conv/fused-pool row-blocking factor (output rows — pooled rows
    for the fused kernel — produced per grid step); the ops layer clips
    it to a divisor of the row count. ``fused`` routes
    ``w1a8_conv3x3_pool`` through the single fused kernel (True) or
    conv-then-reduce_window (False); both routes admit both accum modes
    (the fused kernel has dot and popcount datapaths). All field
    validation happens here at construction — dispatch never rejects a
    config that constructed cleanly.
    """

    op: str = "matmul"
    accum: str = "dot"
    out_step: Optional[float] = None
    interpret: Optional[bool] = None
    use_kernel: bool = True
    bm: Optional[int] = None
    bn: Optional[int] = None
    bk: Optional[int] = None
    rows: int = 1
    fused: bool = True
    source: str = dataclasses.field(default="manual", compare=False)

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        if self.accum not in ACCUMS:
            raise ValueError(
                f"accum must be one of {ACCUMS}, got {self.accum!r}")
        if self.bk is not None and self.bk % PACK:
            raise ValueError(f"bk must be a multiple of {PACK}, got {self.bk}")
        if self.rows < 1:
            raise ValueError(f"rows must be ≥ 1, got {self.rows}")

    # -- call-time resolution ------------------------------------------------

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        import jax
        return jax.default_backend() != "tpu"

    def matmul_tiles(self, m: int, k: int, n: int) -> Tuple[int, int, int]:
        """(bm, bk, bn) with heuristics filling any unset field."""
        bm = self.bm if self.bm is not None else pick_tile(m, DEF_BM, 8)
        bk = self.bk if self.bk is not None else pick_tile(k, DEF_BK, PACK)
        bn = self.bn if self.bn is not None else pick_tile(n, DEF_BN, 128)
        return bm, bk, bn

    def conv_rows(self, h: int) -> int:
        """Largest divisor of `h` that is ≤ self.rows (≥ 1)."""
        r = max(1, min(self.rows, h))
        while h % r:
            r -= 1
        return r

    def replace(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)

    # -- persistence ---------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


# -- shape keys + device ----------------------------------------------------
#
# conv3x3 / conv3x3_pool dims: (h, w, cin, cout) of the *input* plane;
# matmul dims: (m, k, n) with batch folded into m. Batch is deliberately
# not part of the key: the conv grid is parallel over batch and the matmul
# folds it into m, so the structural cell is batch-free.


def device_key() -> str:
    import jax
    kind = jax.devices()[0].device_kind
    return str(kind).strip().lower().replace(" ", "-")


def shape_key(op: str, dims: Sequence[int], accum: str,
              device: Optional[str] = None) -> str:
    dev = device if device is not None else device_key()
    return f"{op}/{'x'.join(str(int(d)) for d in dims)}/{accum}/{dev}"


def parse_key(key: str) -> Tuple[str, Tuple[int, ...], str, str]:
    op, dims, accum, dev = key.split("/", 3)
    return op, tuple(int(d) for d in dims.split("x")), accum, dev


# -- autotune table ---------------------------------------------------------

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_TABLE = _REPO_ROOT / "benchmarks" / "results" / "AUTOTUNE_kernels.json"

_table_cache: Dict[str, Optional[dict]] = {}


def table_path() -> pathlib.Path:
    return pathlib.Path(os.environ.get("REPRO_AUTOTUNE_TABLE",
                                       str(DEFAULT_TABLE)))


def load_table(path: Optional[os.PathLike] = None) -> dict:
    """entries dict (key → record) from the autotune table; {} if absent."""
    p = pathlib.Path(path) if path is not None else table_path()
    ck = str(p)
    if ck not in _table_cache:
        try:
            with open(p) as f:
                _table_cache[ck] = json.load(f).get("entries", {})
        except (OSError, json.JSONDecodeError):
            _table_cache[ck] = {}
    return _table_cache[ck]


def clear_table_cache() -> None:
    _table_cache.clear()


def _shape_distance(a: Sequence[int], b: Sequence[int]) -> float:
    if len(a) != len(b):
        return math.inf
    return sum(abs(math.log(max(x, 1) / max(y, 1))) for x, y in zip(a, b))


def resolve(op: str, dims: Sequence[int], *, accum: str = "dot",
            device: Optional[str] = None,
            table: Optional[dict] = None) -> KernelConfig:
    """Table lookup → nearest-shape fallback → heuristic default.

    Nearest-shape: among same-(op, accum, device) entries, minimal
    log-space distance over dims; ties break on the lexicographically
    smallest key so resolution is deterministic.
    """
    dev = device if device is not None else device_key()
    entries = table if table is not None else load_table()
    key = shape_key(op, dims, accum, dev)
    hit = entries.get(key)
    if hit is not None:
        return KernelConfig.from_dict(
            {**hit["config"], "source": "table"})
    best = None
    for k, rec in entries.items():
        try:
            kop, kdims, kaccum, kdev = parse_key(k)
        except ValueError:
            continue
        if (kop, kaccum, kdev) != (op, accum, dev):
            continue
        d = _shape_distance(dims, kdims)
        if best is None or (d, k) < (best[0], best[1]):
            best = (d, k, rec)
    if best is not None and math.isfinite(best[0]):
        return KernelConfig.from_dict(
            {**best[2]["config"], "source": "nearest"})
    return KernelConfig(op=op, accum=accum, source="heuristic")


def resolve_tuned(op: str, dims: Sequence[int], *,
                  allow_popcount: bool = True,
                  device: Optional[str] = None,
                  table: Optional[dict] = None) -> KernelConfig:
    """Pick the fastest accum variant for the cell, then resolve its config.

    Compares exact-key ``t_us`` across accum modes (``allow_popcount=False``
    restricts to dot for callers that want to opt out); without exact
    entries for both modes it resolves the dot config normally. Popcount is
    always *eligible*: per-channel operands are honoured via the
    uniform-step fold (`core.quant.fold_codes_to_uniform_step`).
    """
    dev = device if device is not None else device_key()
    entries = table if table is not None else load_table()
    accums = ACCUMS if allow_popcount else ("dot",)
    timed = []
    for acc in accums:
        rec = entries.get(shape_key(op, dims, acc, dev))
        if rec is not None and "t_us" in rec:
            timed.append((rec["t_us"], acc))
    accum = min(timed)[1] if timed else "dot"
    return resolve(op, dims, accum=accum, device=dev, table=entries)


# -- legacy-kwarg shim -------------------------------------------------------

_UNSET = object()

# Warn exactly once per process (the ServeEngine pattern); tests reset this
# to re-arm the warning.
_deprecation_warned = False


def _warn_legacy_once() -> None:
    global _deprecation_warned
    if _deprecation_warned:
        return
    _deprecation_warned = True
    warnings.warn(
        "per-call kernel kwargs (accum=/out_step=/interpret=/use_kernel=) "
        "are deprecated; pass config=KernelConfig(...) instead",
        DeprecationWarning, stacklevel=4)


def normalize(op: str, config: Optional[KernelConfig],
              **legacy) -> KernelConfig:
    """Merge a ``config=`` object with legacy per-call kwargs.

    ``config`` given → legacy kwargs must all be unset (TypeError
    otherwise) and ``config.op`` must match. No config → a KernelConfig is
    built from the legacy kwargs (warning once per process if any were
    passed explicitly), preserving each op's historical defaults.
    """
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if config is not None:
        if passed:
            raise TypeError(
                f"pass either config= or legacy kwargs, not both "
                f"(got config and {sorted(passed)})")
        if config.op != op:
            raise ValueError(
                f"config.op={config.op!r} does not match the "
                f"{op!r} entry point")
        return config
    if passed:
        _warn_legacy_once()
    defaults = {"interpret": True}
    if op == "conv3x3_pool":
        defaults["out_step"] = 1.0
    defaults.update(passed)
    return KernelConfig(op=op, source="legacy" if passed else "default",
                        **defaults)
