"""Pallas TPU kernels for the paper's compute hot spots.

w1a8_matmul — bit-packed binary-weight matmul (Mul_prev prologue fusion,
              Div/bias/round/clip epilogue, exact-int8 zero-point variant).
w1a8_conv   — streaming 3×3 conv, the LineBuffer_3x3/Padding-Adapter analogue.

All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU in interpret mode against pure-jnp oracles in ref.py.
"""
from repro.kernels import w1a8_conv, w1a8_matmul  # noqa: F401
