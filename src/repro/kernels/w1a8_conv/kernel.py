"""Pallas TPU kernel: streaming W1A8 3×3 conv — the LineBuffer_3x3 analogue.

The paper's RTL streams rows through a padding adapter + 3-row line buffer so
each input row is fetched from external memory once (§5.2). The TPU-native
equivalent: grid over (batch, output row blocks); per step the BlockSpec
machinery stages ``rows + 2`` input row-stripes of the padded input — the
same array passed once per stripe with shifted index maps — into VMEM, forms
the 3×3 windows by in-register shifts, and contracts on the MXU against ±1
weights unpacked from 1-bit storage. ``rows`` (from `KernelConfig`, default
1) is the row-blocking factor: all ``rows`` output rows of a step share one
(rows·W, K9p) im2col block and one MXU dot, so larger rows amortise grid
overhead at the cost of a taller VMEM working set. Mul_prev prologue +
Div/bias/round/clip epilogue are fused exactly as in ``w1a8_matmul``.

HBM traffic per layer ≈ one read of the uint8 input + 1-bit weights + one
write of the uint8 output — the streaming-dataflow property, ported.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (pltpu.CompilerParams on older jax)
from repro.core.packing import PACK
from repro.core.quant import requant_epilogue
from repro.kernels.w1a8_matmul.kernel import _unpack_tile, _xnor_accumulate


def _im2col_rows(line_rows, nrows: int, w_out: int, k9p: int, dtype):
    """Staged line buffers → (nrows·W, K9p) im2col block in (dy, dx, cin)
    order — the "3x3 window former", one block row per output row."""
    blocks = []
    for r in range(nrows):
        blocks.append(jnp.concatenate(
            [line_rows[r + dy][dx:dx + w_out, :]
             for dy in range(3) for dx in range(3)],
            axis=-1).astype(dtype))                        # (W, 9Cin)
    cols = blocks[0] if nrows == 1 else jnp.concatenate(blocks, axis=0)
    if cols.shape[1] < k9p:                                # K padding lanes
        cols = jnp.pad(cols, ((0, 0), (0, k9p - cols.shape[1])))
    return cols


def _conv_kernel(*refs, rows: int, w_out: int, k9p: int, cout: int,
                 out_step: Optional[float], compute_dtype):
    line_rows = [r[0, 0] for r in refs[:rows + 2]]        # each (Wp, Cin)
    wp_ref, m_ref, d_ref, b_ref, o_ref = refs[rows + 2:]
    cols = _im2col_rows(line_rows, rows, w_out, k9p, jnp.float32)
    am = (cols * m_ref[...].astype(jnp.float32)).astype(compute_dtype)
    signs = _unpack_tile(wp_ref[...], k9p, cout, compute_dtype)
    y = jnp.dot(am, signs, preferred_element_type=jnp.float32)
    y = y * d_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    if out_step is not None:
        y = requant_epilogue(y, out_step, o_ref.dtype)
    o_ref[0] = y.astype(o_ref.dtype).reshape(rows, w_out, cout)


def _conv_popcount_kernel(*refs, rows: int, w_out: int, k9p: int, cout: int,
                          out_step: Optional[float]):
    """Binary-domain conv rows: the im2col codes never leave the 1-bit/8-bit
    domain — bit-planes are packed to uint32 words and contracted against
    the stored weight words with AND+popcount (the FPGA PE's XNOR tree).
    Uniform-Mul_prev contract: ops.py folds the scalar step into Div.
    """
    line_rows = [r[0, 0] for r in refs[:rows + 2]]
    wp_ref, d_ref, b_ref, o_ref = refs[rows + 2:]
    cols = _im2col_rows(line_rows, rows, w_out, k9p, jnp.uint32)
    s = _xnor_accumulate(cols, wp_ref[...], k9p).astype(jnp.float32)
    y = s * d_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    if out_step is not None:
        y = requant_epilogue(y, out_step, o_ref.dtype)
    o_ref[0] = y.astype(o_ref.dtype).reshape(rows, w_out, cout)


def w1a8_conv3x3_pallas(a_pad: jax.Array, w_packed: jax.Array,
                        mul9: jax.Array, div_post: jax.Array,
                        bias: jax.Array, *, out_step: Optional[float] = None,
                        accum: str = "dot", rows: int = 1,
                        compute_dtype=jnp.bfloat16,
                        interpret: bool = False) -> jax.Array:
    """a_pad: (B, H+2, W+2, Cin) uint8 (SAME-padded, K-padding included in
    w/mul layout); w_packed: (K9p/32, Cout); mul9: (1, K9p) with zeros in
    padded lanes; div_post/bias: (1, Cout). Returns (B, H, W, Cout).

    ``rows`` output rows are produced per grid step (H % rows == 0); the
    result is bit-exact across rows choices — each output row's dot sees
    identical operands, only the launch grid changes.

    accum="popcount" contracts in the binary domain (uniform-Mul_prev
    contract — caller folds the scalar step into div_post and passes
    mul9 only for its K9p layout).
    """
    b, hp, wp_, cin = a_pad.shape
    h, w_out = hp - 2, wp_ - 2
    k9p = mul9.shape[1]
    cout = w_packed.shape[1]
    assert w_packed.shape[0] * PACK == k9p
    assert accum in ("dot", "popcount"), accum
    assert h % rows == 0, (h, rows)
    def row(dy):
        return pl.BlockSpec((1, 1, wp_, cin),
                            lambda bb, i, dy=dy: (bb, i * rows + dy, 0, 0))
    row_specs = [row(dy) for dy in range(rows + 2)]
    row_ops = (a_pad,) * (rows + 2)
    wspec = pl.BlockSpec((k9p // PACK, cout), lambda bb, i: (0, 0))
    cspec = pl.BlockSpec((1, cout), lambda bb, i: (0, 0))
    if accum == "popcount":
        kernel = functools.partial(_conv_popcount_kernel, rows=rows,
                                   w_out=w_out, k9p=k9p, cout=cout,
                                   out_step=out_step)
        in_specs = row_specs + [wspec, cspec, cspec]
        operands = row_ops + (w_packed, div_post, bias)
    else:
        kernel = functools.partial(_conv_kernel, rows=rows, w_out=w_out,
                                   k9p=k9p, cout=cout, out_step=out_step,
                                   compute_dtype=compute_dtype)
        in_specs = row_specs + [wspec,
                                pl.BlockSpec((1, k9p), lambda bb, i: (0, 0)),
                                cspec, cspec]
        operands = row_ops + (w_packed, mul9, div_post, bias)
    out_dtype = jnp.float32 if out_step is None else jnp.uint8
    return pl.pallas_call(
        kernel,
        grid=(b, h // rows),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, w_out, cout),
                               lambda bb, i: (bb, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w_out, cout), out_dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
