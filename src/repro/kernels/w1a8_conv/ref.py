"""Pure-jnp oracle for the W1A8 3×3 SAME conv kernel (NHWC, stride 1).

Weight layout: w (3, 3, Cin, Cout) flattened to (9·Cin, Cout) in
(dy, dx, cin) order, matching the kernel's im2col concat order.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import packing
from repro.core.quant import ACT_QMAX, round_half_away


def im2col_3x3(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) → (B, H, W, 9C) patches, SAME zero padding, (dy,dx,c) order."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, dy:dy + h, dx:dx + w, :] for dy in range(3) for dx in range(3)]
    return jnp.concatenate(cols, axis=-1)


def w1a8_conv3x3_ref(a_u8: jnp.ndarray, w_packed: jnp.ndarray, cin: int,
                     mul_prev: jnp.ndarray, div_post: jnp.ndarray,
                     bias: jnp.ndarray,
                     out_step: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """a_u8 (B,H,W,Cin) uint8 codes; w_packed (ceil(9Cin/32), Cout) uint32;
    mul_prev (Cin,); div_post/bias (Cout,)."""
    k = 9 * cin
    signs = packing.unpack_signs(w_packed, k, axis=0, dtype=jnp.float32)
    cols = im2col_3x3(a_u8.astype(jnp.float32))            # (B,H,W,9Cin)
    m9 = jnp.tile(mul_prev.astype(jnp.float32), 9)
    y = (cols * m9) @ signs
    y = y * div_post + bias
    if out_step is None:
        return y
    return jnp.clip(round_half_away(y / out_step), 0, ACT_QMAX).astype(jnp.uint8)
