"""Jit'd public wrappers for the streaming W1A8 3×3 conv kernels.

`w1a8_conv3x3` — conv + fused Mul_prev/Div/bias/round/clip epilogue.
`w1a8_conv3x3_pool` — the same conv with the 2×2 MaxPool fused into the
epilogue (the paper's §5.2 Post+MaxPool stage chain): the conv output never
round-trips through HBM, which is what lets the streaming serving path
(`serve.backends.DetectionBackend`) emit pooled uint8 rows directly.
Bit-exact vs conv-then-reduce_window (same per-row dot shapes, same
rounding, max commutes with the uint8 cast).

Launch configuration (accum mode, row blocking, interpret, fused-vs-split
pool routing) comes from a `KernelConfig` (``config=``); the old per-call
kwargs survive one release behind a DeprecationWarning.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import PACK, pack_signs
from repro.core.quant import fold_codes_to_uniform_step
from repro.kernels import config as _cfg
from repro.kernels.config import KernelConfig, _UNSET
from repro.kernels.w1a8_conv import kernel as _k
from repro.kernels.w1a8_conv import ref as _ref


def conv_pack_weights(w: jax.Array) -> jax.Array:
    """(3, 3, Cin, Cout) float → (ceil(9·Cin/32), Cout) uint32 sign words."""
    k9 = w.shape[0] * w.shape[1] * w.shape[2]
    return pack_signs(w.reshape(k9, w.shape[3]), axis=0)


def conv_mul9(mul_prev: jax.Array) -> jax.Array:
    """(Cin,) input-channel scales → (1, k9p) prologue vector (zeros pad K)."""
    m9 = jnp.tile(mul_prev.astype(jnp.float32), 9)
    k9 = m9.shape[0]
    k9p = (k9 + PACK - 1) // PACK * PACK
    return jnp.pad(m9, (0, k9p - k9)).reshape(1, k9p)


def w1a8_conv3x3(a_u8: jax.Array, w_packed: jax.Array, mul_prev: jax.Array,
                 div_post: jax.Array, bias: jax.Array, *, cin: int,
                 config: Optional[KernelConfig] = None,
                 out_step=_UNSET, accum=_UNSET, interpret=_UNSET,
                 use_kernel=_UNSET) -> jax.Array:
    """Streaming 3×3 SAME conv on uint8 codes.

    a_u8 (B,H,W,Cin); w_packed (ceil(9Cin/32),Cout); mul_prev (Cin,);
    div_post/bias (Cout,). Returns (B,H,W,Cout) f32, or uint8 if
    config.out_step is set.

    config.accum="popcount" contracts in the binary domain (XNOR-popcount
    instead of unpack-then-dot). That path cannot apply a per-input-channel
    Mul_prev inside the bit-packed accumulation; a per-channel mul_prev is
    honoured by requantizing the codes onto the max step m̄ first
    (`core.quant.fold_codes_to_uniform_step`) and folding m̄ into
    Div_current: ``S·(div·m̄) + bias`` — the exact same f32 epilogue
    expression as the dot path with canonical ``(mul=1, div·m)`` operands.
    Under a uniform mul_prev the fold is a bit-exact identity, so the
    popcount-vs-dot bit-exactness contract holds; under per-channel steps
    it is an ≤½-LSB-per-channel approximation (the producer-side fold in
    ``models/yolo.py`` avoids even that by emitting uniform-step codes).
    """
    cfg = _cfg.normalize("conv3x3", config, out_step=out_step, accum=accum,
                         interpret=interpret, use_kernel=use_kernel)
    cfg = cfg.replace(interpret=cfg.resolved_interpret())
    return _w1a8_conv3x3(a_u8, w_packed, mul_prev, div_post, bias,
                         cin=cin, config=cfg)


@functools.partial(jax.jit, static_argnames=("cin", "config"))
def _w1a8_conv3x3(a_u8, w_packed, mul_prev, div_post, bias, *, cin: int,
                  config: KernelConfig) -> jax.Array:
    out_step = config.out_step
    if not config.use_kernel:
        return _ref.w1a8_conv3x3_ref(
            a_u8, w_packed, cin, mul_prev, div_post, bias,
            None if out_step is None else jnp.float32(out_step))
    mul9 = conv_mul9(mul_prev)
    k9p = mul9.shape[1]
    wp = w_packed
    if wp.shape[0] != k9p // PACK:
        wp = jnp.pad(wp, ((0, k9p // PACK - wp.shape[0]), (0, 0)))
    cout = wp.shape[1]
    dv = div_post.astype(jnp.float32).reshape(1, cout)
    if config.accum == "popcount":
        a_u8, mbar = fold_codes_to_uniform_step(a_u8, mul_prev)
        dv = dv * mbar
    a_pad = jnp.pad(a_u8, ((0, 0), (1, 1), (1, 1), (0, 0)))
    return _k.w1a8_conv3x3_pallas(
        a_pad, wp, mul9, dv,
        bias.astype(jnp.float32).reshape(1, cout),
        out_step=out_step, accum=config.accum,
        rows=config.conv_rows(a_u8.shape[1]),
        interpret=config.interpret)


def w1a8_conv3x3_pool(a_u8: jax.Array, w_packed: jax.Array,
                      mul_prev: jax.Array, div_post: jax.Array,
                      bias: jax.Array, *, cin: int,
                      config: Optional[KernelConfig] = None,
                      out_step=_UNSET, interpret=_UNSET,
                      use_kernel=_UNSET) -> jax.Array:
    """Streaming 3×3 SAME conv + requant + 2×2 MaxPool.

    Same contract as `w1a8_conv3x3` with a quantizing epilogue, but H and W
    must be even and the output is the pooled (B, H/2, W/2, Cout) uint8
    code plane. config.fused=True (default) runs the single fused kernel
    (`fused_pool.w1a8_conv3x3_pool2`); config.fused=False runs the conv
    kernel then `reduce_window`. Both routes admit both accum modes and
    are bit-exact against each other (max commutes with the uint8 cast;
    the popcount contraction is integer-exact).
    """
    cfg = _cfg.normalize("conv3x3_pool", config, out_step=out_step,
                         interpret=interpret, use_kernel=use_kernel)
    cfg = cfg.replace(interpret=cfg.resolved_interpret())
    if cfg.out_step is None:
        cfg = cfg.replace(out_step=1.0)
    return _w1a8_conv3x3_pool(a_u8, w_packed, mul_prev, div_post, bias,
                              cin=cin, config=cfg)


@functools.partial(jax.jit, static_argnames=("cin", "config"))
def _w1a8_conv3x3_pool(a_u8, w_packed, mul_prev, div_post, bias, *,
                       cin: int, config: KernelConfig) -> jax.Array:
    out_step = config.out_step
    if not config.use_kernel:
        out = _ref.w1a8_conv3x3_ref(a_u8, w_packed, cin, mul_prev, div_post,
                                    bias, jnp.float32(out_step))
        return jax.lax.reduce_window(out, jnp.uint8(0), jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    if not config.fused:
        out = _w1a8_conv3x3(a_u8, w_packed, mul_prev, div_post, bias,
                            cin=cin, config=config.replace(op="conv3x3"))
        return jax.lax.reduce_window(out, jnp.uint8(0), jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    from repro.kernels.w1a8_conv.fused_pool import w1a8_conv3x3_pool2
    dv = div_post
    if config.accum == "popcount":
        a_u8, mbar = fold_codes_to_uniform_step(a_u8, mul_prev)
        dv = div_post.astype(jnp.float32) * mbar
    return w1a8_conv3x3_pool2(a_u8, w_packed, mul_prev, dv, bias,
                              cin=cin, out_step=out_step, accum=config.accum,
                              rows=config.conv_rows(a_u8.shape[1] // 2),
                              interpret=config.interpret)
