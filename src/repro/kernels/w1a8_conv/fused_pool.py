"""Fused W1A8 conv3x3 + requant + 2×2 MaxPool — the paper's Post+MaxPool
pipeline stage (§5.2, Table 1 layers conv1–4, conv7) as one Pallas kernel.

Grid over (batch, pooled output row blocks): each step stages
``2·rows + 2`` input row-stripes (the line buffers for ``2·rows`` conv
rows, halo included), computes all conv rows with one contraction over a
(2·rows·W, K9p) im2col block — an MXU dot for ``accum="dot"``, bit-plane
AND+popcount (`_xnor_accumulate`) for ``accum="popcount"`` — applies the
Mul_prev/Div/bias/round/clip epilogue, and max-reduces 2×2 windows —
``rows`` pooled uint8 rows go to HBM per step. Activation traffic for a
pool layer drops from (write HW + read HW + write HW/4) to (write HW/4):
the conv output never exists in HBM, exactly like the RTL stage chain.
The popcount route never leaves the bit domain between line buffer and
pooled codes — conv, quantization post-processing and max pooling run as
one dataflow, which is the paper's whole §5.2 stage chain in one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (pltpu.CompilerParams on older jax)
from repro.core.packing import PACK
from repro.core.quant import requant_epilogue
from repro.kernels.w1a8_matmul.kernel import _unpack_tile, _xnor_accumulate
from repro.kernels.w1a8_conv.kernel import _im2col_rows


def _pool_epilogue(y, out_step, nconv: int, w_out: int, cout: int, o_ref):
    # f32 carrier for the 2×2 max; values are exact uint8 codes
    y = requant_epilogue(y, out_step, jnp.float32)
    y = y.reshape(nconv, w_out, cout)
    both = jnp.maximum(y[0::2], y[1::2])                # vertical 2-max
    pooled = jnp.maximum(both[:, 0::2, :], both[:, 1::2, :])  # horizontal
    o_ref[0] = pooled.astype(o_ref.dtype)


def _kernel(*refs, rows: int, w_out: int, k9p: int, cout: int,
            out_step: float, compute_dtype):
    nconv = 2 * rows
    line_rows = [r[0, 0] for r in refs[:nconv + 2]]
    wp_ref, m_ref, d_ref, b_ref, o_ref = refs[nconv + 2:]
    signs = _unpack_tile(wp_ref[...], k9p, cout, compute_dtype)
    cols = _im2col_rows(line_rows, nconv, w_out, k9p, jnp.float32)
    am = (cols * m_ref[...].astype(jnp.float32)).astype(compute_dtype)
    y = jnp.dot(am, signs, preferred_element_type=jnp.float32)
    y = (y * d_ref[...].astype(jnp.float32)
         + b_ref[...].astype(jnp.float32))
    _pool_epilogue(y, out_step, nconv, w_out, cout, o_ref)


def _popcount_kernel(*refs, rows: int, w_out: int, k9p: int, cout: int,
                     out_step: float):
    """Binary-domain fused conv+pool: the im2col codes stay uint32 bit
    planes, contracted against the stored weight words with AND+popcount
    (the FPGA PE's XNOR tree); requant + 2×2 max fold into the same step.
    Uniform-Mul_prev contract: ops.py folds the scalar step into Div.
    """
    nconv = 2 * rows
    line_rows = [r[0, 0] for r in refs[:nconv + 2]]
    wp_ref, d_ref, b_ref, o_ref = refs[nconv + 2:]
    cols = _im2col_rows(line_rows, nconv, w_out, k9p, jnp.uint32)
    s = _xnor_accumulate(cols, wp_ref[...], k9p).astype(jnp.float32)
    y = s * d_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    _pool_epilogue(y, out_step, nconv, w_out, cout, o_ref)


def w1a8_conv3x3_pool2(a_u8: jax.Array, w_packed: jax.Array,
                       mul_prev: jax.Array, div_post: jax.Array,
                       bias: jax.Array, *, cin: int, out_step: float,
                       accum: str = "dot", rows: int = 1,
                       compute_dtype=jnp.bfloat16,
                       interpret: bool = True) -> jax.Array:
    """a_u8 (B,H,W,Cin) uint8 (H,W even) → (B,H/2,W/2,Cout) uint8 codes.

    ``rows`` pooled rows per grid step ((H/2) % rows == 0); bit-exact
    across rows choices — per-conv-row contraction operands are unchanged.

    accum="popcount" contracts in the binary domain (uniform-Mul_prev
    contract — caller folds the scalar step into div_post; mul_prev is
    used only for its K9p layout). The integer accumulation is exact and
    shares the dot path's f32 epilogue expression, so under canonical
    ``(mul=1, div·m)`` operands the two accum modes are bit-exact.
    """
    from repro.kernels.w1a8_conv.ops import conv_mul9
    b, h, w, _ = a_u8.shape
    a_pad = jnp.pad(a_u8, ((0, 0), (1, 1), (1, 1), (0, 0)))
    mul9 = conv_mul9(mul_prev)
    k9p = mul9.shape[1]
    wp = w_packed
    if wp.shape[0] != k9p // PACK:
        wp = jnp.pad(wp, ((0, k9p // PACK - wp.shape[0]), (0, 0)))
    cout = wp.shape[1]
    wp_ = w + 2
    assert accum in ("dot", "popcount"), accum
    assert (h // 2) % rows == 0, (h, rows)
    def row(dy):
        return pl.BlockSpec(
            (1, 1, wp_, cin),
            lambda bb, i, dy=dy: (bb, 2 * rows * i + dy, 0, 0))
    nconv = 2 * rows
    row_specs = [row(dy) for dy in range(nconv + 2)]
    row_ops = (a_pad,) * (nconv + 2)
    wspec = pl.BlockSpec((k9p // PACK, cout), lambda bb, i: (0, 0))
    cspec = pl.BlockSpec((1, cout), lambda bb, i: (0, 0))
    dv = div_post.astype(jnp.float32).reshape(1, cout)
    bs = bias.astype(jnp.float32).reshape(1, cout)
    if accum == "popcount":
        kernel = functools.partial(_popcount_kernel, rows=rows, w_out=w,
                                   k9p=k9p, cout=cout, out_step=out_step)
        in_specs = row_specs + [wspec, cspec, cspec]
        operands = row_ops + (wp, dv, bs)
    else:
        kernel = functools.partial(_kernel, rows=rows, w_out=w, k9p=k9p,
                                   cout=cout, out_step=out_step,
                                   compute_dtype=compute_dtype)
        in_specs = row_specs + [wspec,
                                pl.BlockSpec((1, k9p), lambda bb, i: (0, 0)),
                                cspec, cspec]
        operands = row_ops + (wp, mul9, dv, bs)
    return pl.pallas_call(
        kernel,
        grid=(b, (h // 2) // rows),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, w // 2, cout),
                               lambda bb, i: (bb, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, cout), jnp.uint8),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
