"""Fused W1A8 conv3x3 + requant + 2×2 MaxPool — the paper's Post+MaxPool
pipeline stage (§5.2, Table 1 layers conv1–4, conv7) as one Pallas kernel.

Grid over (batch, pooled output rows): each step stages FOUR input
row-stripes (two conv rows' line buffers, halo included), computes both conv
rows, applies the Mul_prev/Div/bias/round/clip epilogue, and max-reduces
2×2 windows — the pooled uint8 row goes to HBM. Activation traffic for a
pool layer drops from (write HW + read HW + write HW/4) to (write HW/4):
the conv output never exists in HBM, exactly like the RTL stage chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (pltpu.CompilerParams on older jax)
from repro.core.packing import PACK
from repro.core.quant import requant_epilogue
from repro.kernels.w1a8_matmul.kernel import _unpack_tile


def _kernel(r0_ref, r1_ref, r2_ref, r3_ref, wp_ref, m_ref, d_ref, b_ref,
            o_ref, *, w_out: int, k9p: int, cout: int, out_step: float,
            compute_dtype):
    rows = [r0_ref[0, 0], r1_ref[0, 0], r2_ref[0, 0], r3_ref[0, 0]]
    signs = _unpack_tile(wp_ref[...], k9p, cout, compute_dtype)
    m = m_ref[...].astype(jnp.float32)
    div = d_ref[...].astype(jnp.float32)
    bias = b_ref[...].astype(jnp.float32)

    def conv_row(top):                              # 3 stacked line buffers
        cols = jnp.concatenate(
            [rows[top + dy][dx:dx + w_out, :] for dy in range(3)
             for dx in range(3)], axis=-1).astype(jnp.float32)
        if cols.shape[1] < k9p:
            cols = jnp.pad(cols, ((0, 0), (0, k9p - cols.shape[1])))
        am = (cols * m).astype(compute_dtype)
        y = jnp.dot(am, signs, preferred_element_type=jnp.float32)
        y = y * div + bias
        # f32 carrier for the 2×2 max; values are exact uint8 codes
        return requant_epilogue(y, out_step, jnp.float32)    # (W, Cout)

    y0 = conv_row(0)
    y1 = conv_row(1)
    both = jnp.maximum(y0, y1)                       # vertical 2-max
    pooled = jnp.maximum(both[0::2, :], both[1::2, :])  # horizontal 2-max
    o_ref[0, 0] = pooled.astype(o_ref.dtype)


def w1a8_conv3x3_pool2(a_u8: jax.Array, w_packed: jax.Array,
                       mul_prev: jax.Array, div_post: jax.Array,
                       bias: jax.Array, *, cin: int, out_step: float,
                       compute_dtype=jnp.bfloat16,
                       interpret: bool = True) -> jax.Array:
    """a_u8 (B,H,W,Cin) uint8 (H,W even) → (B,H/2,W/2,Cout) uint8 codes."""
    from repro.kernels.w1a8_conv.ops import conv_mul9
    b, h, w, _ = a_u8.shape
    a_pad = jnp.pad(a_u8, ((0, 0), (1, 1), (1, 1), (0, 0)))
    mul9 = conv_mul9(mul_prev)
    k9p = mul9.shape[1]
    wp = w_packed
    if wp.shape[0] != k9p // PACK:
        wp = jnp.pad(wp, ((0, k9p // PACK - wp.shape[0]), (0, 0)))
    cout = wp.shape[1]
    wp_, hp = w + 2, h + 2
    kernel = functools.partial(_kernel, w_out=w, k9p=k9p, cout=cout,
                               out_step=out_step, compute_dtype=compute_dtype)
    def row(dy):
        return pl.BlockSpec((1, 1, wp_, cin),
                            lambda bb, i, dy=dy: (bb, 2 * i + dy, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h // 2),
        in_specs=[row(0), row(1), row(2), row(3),
                  pl.BlockSpec((k9p // PACK, cout), lambda bb, i: (0, 0)),
                  pl.BlockSpec((1, k9p), lambda bb, i: (0, 0)),
                  pl.BlockSpec((1, cout), lambda bb, i: (0, 0)),
                  pl.BlockSpec((1, cout), lambda bb, i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1, w // 2, cout),
                               lambda bb, i: (bb, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, cout), jnp.uint8),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_pad, a_pad, a_pad, a_pad, wp, mul9,
      div_post.astype(jnp.float32).reshape(1, cout),
      bias.astype(jnp.float32).reshape(1, cout))
