"""W1A8 w1a8_conv kernel package."""
from repro.kernels.w1a8_conv import kernel, ops, ref  # noqa: F401
