"""Pure-jnp oracle for the W1A8 packed matmul kernel.

Semantics (paper Eqs. 3-2/3-4 + §3.2 post-processing):
    y[m, n] = (Σ_k sign[k, n] · (mul_prev[k] · a[m, k])) · div_post[n] + bias[n]
optionally requantized to uint8 codes with step ``out_step``:
    q[m, n] = clip(round(y / out_step), 0, 255).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import packing
from repro.core.quant import ACT_QMAX, round_half_away


def w1a8_matmul_ref(a_u8: jnp.ndarray, w_packed: jnp.ndarray, k: int,
                    mul_prev: jnp.ndarray, div_post: jnp.ndarray,
                    bias: jnp.ndarray,
                    out_step: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    signs = packing.unpack_signs(w_packed, k, axis=0, dtype=jnp.float32)
    am = a_u8.astype(jnp.float32) * mul_prev.astype(jnp.float32)
    y = am @ signs
    y = y * div_post + bias
    if out_step is None:
        return y
    q = jnp.clip(round_half_away(y / out_step), 0, ACT_QMAX)
    return q.astype(jnp.uint8)
