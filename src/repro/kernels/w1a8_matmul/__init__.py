"""W1A8 w1a8_matmul kernel package."""
from repro.kernels.w1a8_matmul import kernel, ops, ref  # noqa: F401
