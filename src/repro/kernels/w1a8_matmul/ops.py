"""Jit'd public wrapper for the W1A8 packed matmul kernel.

Handles batching (leading dims folded into M), padding to tile multiples
(zero activations × zero mul_prev ⇒ padded K contributes exactly 0), tile
resolution through `KernelConfig` (explicit bm/bk/bn or the heuristic
auto-shrink), and CPU fallback (interpret mode / jnp ref).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import PACK, pack_signs
from repro.core.quant import fold_codes_to_uniform_step
from repro.kernels import config as _cfg
from repro.kernels.config import KernelConfig, _UNSET, _round_up
from repro.kernels.w1a8_matmul import kernel as _k
from repro.kernels.w1a8_matmul import ref as _ref


def w1a8_matmul(a_u8: jax.Array, w_packed: jax.Array, mul_prev: jax.Array,
                div_post: jax.Array, bias: jax.Array, *, k: int,
                config: Optional[KernelConfig] = None,
                out_step=_UNSET, accum=_UNSET, interpret=_UNSET,
                use_kernel=_UNSET) -> jax.Array:
    """y = ((a ⊙ mul_prev) @ unpack(w_packed)) ⊙ div_post + bias  [+ requant].

    a_u8: (..., K) uint8 codes; w_packed: (ceil(K/32), N) uint32;
    mul_prev: (K,) f32; div_post, bias: (N,) f32.

    Launch configuration comes from ``config=`` (a `KernelConfig`, op
    "matmul"); the old per-call kwargs survive one release behind a
    DeprecationWarning. config.accum="popcount": XNOR-popcount contraction.
    A per-channel mul_prev is honoured by requantizing the codes onto the
    max step m̄ (`core.quant.fold_codes_to_uniform_step`), which then folds
    into div_post; under a uniform mul_prev the fold is a bit-exact
    identity, so the epilogue — and the rounding — matches the dot path
    bit for bit.
    """
    cfg = _cfg.normalize("matmul", config, out_step=out_step, accum=accum,
                         interpret=interpret, use_kernel=use_kernel)
    cfg = cfg.replace(interpret=cfg.resolved_interpret())
    return _w1a8_matmul(a_u8, w_packed, mul_prev, div_post, bias,
                        k=k, config=cfg)


@functools.partial(jax.jit, static_argnames=("k", "config"))
def _w1a8_matmul(a_u8, w_packed, mul_prev, div_post, bias, *, k: int,
                 config: KernelConfig) -> jax.Array:
    out_step = config.out_step
    if not config.use_kernel:
        y = _ref.w1a8_matmul_ref(a_u8, w_packed, k, mul_prev, div_post, bias,
                                 None if out_step is None else jnp.float32(out_step))
        return y

    lead = a_u8.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    n = w_packed.shape[1]
    a2 = a_u8.reshape(m, a_u8.shape[-1])

    bm, bk, bn = config.matmul_tiles(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    a2 = jnp.pad(a2[:, :k], ((0, mp - m), (0, kp - k)))
    mul = jnp.pad(mul_prev.astype(jnp.float32), (0, kp - k)).reshape(1, kp)
    wp = w_packed
    if kp // PACK != wp.shape[0] or np_ != n:
        wp = jnp.pad(wp, ((0, kp // PACK - wp.shape[0]), (0, np_ - n)))
    dv = jnp.pad(div_post.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)
    bs = jnp.pad(bias.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)

    if config.accum == "popcount":
        # zero-padded K lanes carry zero codes (ratio 0 · zero pad) and
        # contribute 0 to popcount on their own — no mul operand needed,
        # the uniformized m̄ folds into Div_current.
        a2, mbar = fold_codes_to_uniform_step(a2, mul.reshape(-1))
        dv = dv * mbar
        y = _k.w1a8_matmul_popcount_pallas(a2, wp, dv, bs, out_step=out_step,
                                           bm=bm, bk=bk, bn=bn,
                                           interpret=config.interpret)
    else:
        y = _k.w1a8_matmul_pallas(a2, wp, mul, dv, bs, out_step=out_step,
                                  bm=bm, bk=bk, bn=bn,
                                  interpret=config.interpret)
    return y[:m, :n].reshape(lead + (n,))


def w1a8_pack_weights(w: jax.Array) -> jax.Array:
    """(K, N) float → (ceil(K/32), N) uint32 sign words (deploy-time)."""
    return pack_signs(w, axis=0)
