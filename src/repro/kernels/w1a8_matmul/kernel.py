"""Pallas TPU kernel: bit-packed W1A8 matmul with fused scale split.

TPU adaptation of the paper's binary PE (§5.2):
  * weights live in HBM as 1 bit each (uint32 words, reduction-major) and are
    unpacked to ±1 *inside* the kernel's VMEM tiles — HBM weight traffic is
    1/16 of bf16 (the COE/BRAM-ROM streaming analogue),
  * ``Mul_prev`` (per-input-channel) is applied in the **prologue**, before
    the MXU contraction — Eq. 3-4's "compensation during accumulation",
  * ``Div_current``/bias/round/clip run in the **epilogue** on the final
    K-step, optionally emitting uint8 codes for the next layer (the paper's
    Post-process module, fused).

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary"); f32 accumulation in a
VMEM scratch tile. MXU operands are bf16 (entries |m·a| ≤ 255·m exactly
representable errs <0.4%, validated vs. ref to corr>0.99999) or, in the
``exact`` path (uniform scale), int8 with the zero-point trick:
  Σ_k s·a = Σ_k s·(a−128) + 128·Σ_k s   (a−128 ∈ int8, exact int32 MXU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (pltpu.CompilerParams on older jax)
from repro.core.packing import PACK
from repro.core.quant import requant_epilogue

DEF_BM, DEF_BK, DEF_BN = 256, 512, 256


def _unpack_tile(wp_tile: jax.Array, bk: int, bn: int, dtype) -> jax.Array:
    """(bk/32, bn) uint32 → (bk, bn) ±1 in `dtype`, in VMEM."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bk // PACK, PACK, bn), 1)
    bits = (wp_tile[:, None, :] >> shifts) & jnp.uint32(1)
    signs = bits.astype(jnp.int8) * jnp.int8(2) - jnp.int8(1)
    return signs.reshape(bk, bn).astype(dtype)


def _pack_act_bitplane(a_u32: jax.Array, bit: int, kp: int) -> jax.Array:
    """Bit-plane ``bit`` of uint8 codes (M, Kp) → (M, Kp/32) uint32 words.

    Same LSB-first convention as ``core.packing.pack_signs`` so the words
    AND directly against the stored weight sign words.
    """
    m = a_u32.shape[0]
    bits = (a_u32 >> jnp.uint32(bit)) & jnp.uint32(1)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (m, kp // PACK, PACK), 2)
    return jnp.sum(bits.reshape(m, kp // PACK, PACK) << shifts, axis=2,
                   dtype=jnp.uint32)


def _xnor_accumulate(a_u32: jax.Array, wp_tile: jax.Array,
                     kp: int) -> jax.Array:
    """Σ_k sign_k·a_k via XNOR-popcount on packed words — exact int32.

    a_u32: (M, Kp) uint8 codes held as uint32; wp_tile: (Kp/32, N) sign
    words (bit=1 ⇔ +1). FracBNN-style bit decomposition: a = Σ_b 2^b·a_b
    with a_b ∈ {0,1}, and for each binary plane
        Σ_k s_k·a_{b,k} = 2·popcount(w ∧ a_b) − popcount(a_b)
    so the whole inner product is bitwise AND + population_count — no
    unpack, no multiply. Zero codes contribute 0 to both terms, so K
    padding lanes (zero activations, +1 weight pad bits) are free.
    """
    acc = jnp.zeros((a_u32.shape[0], wp_tile.shape[1]), jnp.int32)
    for bit in range(8):
        words = _pack_act_bitplane(a_u32, bit, kp)          # (M, Kp/32)
        pc = jnp.sum(jax.lax.population_count(
            words[:, :, None] & wp_tile[None, :, :]).astype(jnp.int32),
            axis=1)                                          # (M, N)
        cnt = jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                      axis=1, keepdims=True)                 # (M, 1)
        acc = acc + ((2 * pc - cnt) << bit)
    return acc


def _matmul_kernel(a_ref, wp_ref, m_ref, d_ref, b_ref, o_ref, acc_ref, *,
                   nk: int, bk: int, bn: int, out_step: Optional[float],
                   compute_dtype):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Prologue: per-input-channel Mul_prev fused before the contraction.
    a = a_ref[...].astype(jnp.float32)            # (bm, bk) uint8 → f32
    am = (a * m_ref[...].astype(jnp.float32)).astype(compute_dtype)
    signs = _unpack_tile(wp_ref[...], bk, bn, compute_dtype)
    acc_ref[...] += jnp.dot(am, signs, preferred_element_type=jnp.float32)

    # Epilogue on the last K step: Div_current, bias, (round, clip).
    @pl.when(kk == nk - 1)
    def _epilogue():
        y = acc_ref[...] * d_ref[...].astype(jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if out_step is None:
            o_ref[...] = y.astype(o_ref.dtype)
        else:
            o_ref[...] = requant_epilogue(y, out_step, o_ref.dtype)


def _popcount_matmul_kernel(a_ref, wp_ref, d_ref, b_ref, o_ref, acc_ref, *,
                            nk: int, bk: int, out_step: Optional[float]):
    """XNOR-popcount accumulation (uniform-Mul_prev contract).

    No per-input-channel prologue is possible once the activations are bit
    packed, so this path requires a uniform input step; ops.py folds that
    scalar into Div_current so the epilogue expression — and hence the
    rounding — is identical to the dot path's.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _xnor_accumulate(a_ref[...].astype(jnp.uint32),
                                     wp_ref[...], bk)

    @pl.when(kk == nk - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * d_ref[...].astype(jnp.float32) \
            + b_ref[...].astype(jnp.float32)
        if out_step is None:
            o_ref[...] = y.astype(o_ref.dtype)
        else:
            o_ref[...] = requant_epilogue(y, out_step, o_ref.dtype)


def w1a8_matmul_popcount_pallas(a_u8: jax.Array, w_packed: jax.Array,
                                div_post: jax.Array, bias: jax.Array, *,
                                out_step: Optional[float] = None,
                                bm: int = DEF_BM, bk: int = DEF_BK,
                                bn: int = DEF_BN,
                                interpret: bool = False) -> jax.Array:
    """Binary-domain matmul: same shapes/epilogue as ``w1a8_matmul_pallas``
    minus the Mul_prev operand (already folded into ``div_post``)."""
    m, k = a_u8.shape
    n = w_packed.shape[1]
    assert k % bk == 0 and m % bm == 0 and n % bn == 0 and bk % PACK == 0
    nk = k // bk
    kernel = functools.partial(_popcount_matmul_kernel, nk=nk, bk=bk,
                               out_step=out_step)
    out_dtype = jnp.float32 if out_step is None else jnp.uint8
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // PACK, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_u8, w_packed, div_post, bias)


def w1a8_matmul_pallas(a_u8: jax.Array, w_packed: jax.Array,
                       mul_prev: jax.Array, div_post: jax.Array,
                       bias: jax.Array, *,
                       out_step: Optional[float] = None,
                       bm: int = DEF_BM, bk: int = DEF_BK, bn: int = DEF_BN,
                       compute_dtype=jnp.bfloat16,
                       interpret: bool = False) -> jax.Array:
    """Shapes (pre-padded to tile multiples by ops.py):
    a_u8 (M, K) uint8 · w_packed (K/32, N) uint32 · mul_prev (1, K) f32 ·
    div_post/bias (1, N) f32 → (M, N) f32, or uint8 codes when out_step given.
    """
    m, k = a_u8.shape
    n = w_packed.shape[1]
    assert k % bk == 0 and m % bm == 0 and n % bn == 0 and bk % PACK == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_matmul_kernel, nk=nk, bk=bk, bn=bn,
                               out_step=out_step, compute_dtype=compute_dtype)
    out_dtype = jnp.float32 if out_step is None else jnp.uint8
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // PACK, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_u8, w_packed, mul_prev, div_post, bias)


# ---------------------------------------------------------------------------
# Exact integer path (uniform input scale): int8 MXU + zero-point correction.
# ---------------------------------------------------------------------------

def _int_kernel(a_ref, wp_ref, cs_ref, o_ref, acc_ref, *, nk, bk, bn):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_c = (a_ref[...].astype(jnp.int32) - 128).astype(jnp.int8)
    signs = _unpack_tile(wp_ref[...], bk, bn, jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        a_c, signs, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == nk - 1)
    def _fin():
        # zero-point correction: + 128 · Σ_k sign[k, n]  (colsum, precomputed)
        o_ref[...] = acc_ref[...] + 128 * cs_ref[...]


def w1a8_matmul_int_pallas(a_u8: jax.Array, w_packed: jax.Array,
                           colsum: jax.Array, *, bm: int = DEF_BM,
                           bk: int = DEF_BK, bn: int = DEF_BN,
                           interpret: bool = False) -> jax.Array:
    """Exact Σ_k s·a in int32. colsum: (1, N) int32 = Σ_k sign[k, n]."""
    m, k = a_u8.shape
    n = w_packed.shape[1]
    assert k % bk == 0 and m % bm == 0 and n % bn == 0
    nk = k // bk
    kernel = functools.partial(_int_kernel, nk=nk, bk=bk, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // PACK, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_u8, w_packed, colsum)
