"""Deterministic synthetic data pipelines (offline container — no VOC/web).

Stateless index-based sampling: batch(step) is a pure function of
(seed, step, host_shard), so the pipeline "state" in a checkpoint is just
the step counter — restart/elastic-rescale resume exactly.
"""
from repro.data.pipeline import (detection_batch, lm_batch,  # noqa: F401
                                 make_detection_dataset, make_lm_dataset)
