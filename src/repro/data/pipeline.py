"""Synthetic-but-structured datasets, deterministic by (seed, step).

LM:        Zipf-ish token streams with induced bigram structure so the loss
           actually decreases (models can learn the transition table).
Detection: images composed of colored rectangles on noise; labels are the
           ground-truth boxes — the YOLO QAT e2e example trains on these.

Both samplers are pure functions of (seed, step, shard) — no iterator state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.yolo import GRID, INPUT_SIZE, NUM_ANCHORS, NUM_CLASSES


@dataclasses.dataclass(frozen=True)
class LMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def make_lm_dataset(vocab_size: int, seq_len: int, global_batch: int,
                    seed: int = 0) -> LMDataset:
    return LMDataset(vocab_size, seq_len, global_batch, seed)


def lm_batch(ds: LMDataset, step, *, shard: int = 0, num_shards: int = 1):
    """→ (tokens, labels) each (global_batch/num_shards, seq_len) int32.

    Token stream: x_{t+1} = (a·x_t + c_b) mod V with per-sequence phase —
    a learnable deterministic structure (bigram table) + 10% uniform noise.
    """
    bsz = ds.global_batch // num_shards
    key = jax.random.fold_in(jax.random.PRNGKey(ds.seed), step)
    key = jax.random.fold_in(key, shard)
    k1, k2, k3 = jax.random.split(key, 3)
    v = ds.vocab_size
    x0 = jax.random.randint(k1, (bsz, 1), 0, v)
    mult = 31 % v or 1
    offs = jax.random.randint(k2, (bsz, 1), 0, 7)

    def stepf(x, _):
        nxt = (x * mult + offs) % v
        return nxt, nxt

    _, seq = jax.lax.scan(stepf, x0, None, length=ds.seq_len)
    seq = jnp.swapaxes(seq[..., 0], 0, 1)                   # (B, S)
    noise = jax.random.bernoulli(k3, 0.1, seq.shape)
    rand = jax.random.randint(jax.random.fold_in(k3, 1), seq.shape, 0, v)
    tokens = jnp.where(noise, rand, seq).astype(jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    return tokens, labels


@dataclasses.dataclass(frozen=True)
class DetectionDataset:
    global_batch: int
    seed: int = 0
    max_boxes: int = 4


def make_detection_dataset(global_batch: int, seed: int = 0,
                           max_boxes: int = 4) -> DetectionDataset:
    return DetectionDataset(global_batch, seed, max_boxes)


def detection_batch(ds: DetectionDataset, step, *, shard: int = 0,
                    num_shards: int = 1):
    """→ images (B,320,320,3) f32 in [0,1]; boxes (B,M,4) cxcywh;
    classes (B,M) int32 (−1 = no box)."""
    bsz = ds.global_batch // num_shards
    key = jax.random.fold_in(jax.random.PRNGKey(ds.seed + 77), step)
    key = jax.random.fold_in(key, shard)
    kb, kc, kn, kcol = jax.random.split(key, 4)
    m = ds.max_boxes
    cx = jax.random.uniform(kb, (bsz, m), minval=0.15, maxval=0.85)
    cy = jax.random.uniform(jax.random.fold_in(kb, 1), (bsz, m),
                            minval=0.15, maxval=0.85)
    w = jax.random.uniform(jax.random.fold_in(kb, 2), (bsz, m),
                           minval=0.1, maxval=0.3)
    h = jax.random.uniform(jax.random.fold_in(kb, 3), (bsz, m),
                           minval=0.1, maxval=0.3)
    boxes = jnp.stack([cx, cy, w, h], -1)
    classes = jax.random.randint(kc, (bsz, m), 0, NUM_CLASSES)
    present = jax.random.bernoulli(jax.random.fold_in(kc, 1), 0.8, (bsz, m))
    classes = jnp.where(present, classes, -1)

    # paint rectangles whose colour encodes the class (learnable signal)
    yy = (jnp.arange(INPUT_SIZE) + 0.5) / INPUT_SIZE
    xx = (jnp.arange(INPUT_SIZE) + 0.5) / INPUT_SIZE
    inside = ((yy[None, :, None, None] > (cy - h / 2)[:, None, None, :]) &
              (yy[None, :, None, None] < (cy + h / 2)[:, None, None, :]) &
              (xx[None, None, :, None] > (cx - w / 2)[:, None, None, :]) &
              (xx[None, None, :, None] < (cx + w / 2)[:, None, None, :]) &
              present[:, None, None, :])                     # (B,H,W,M)
    col = jnp.stack([(classes % 5).astype(jnp.float32) / 5.0 + 0.2,
                     (classes % 7).astype(jnp.float32) / 7.0 + 0.1,
                     (classes % 3).astype(jnp.float32) / 3.0 + 0.3], -1)
    img = jax.random.uniform(kn, (bsz, INPUT_SIZE, INPUT_SIZE, 3)) * 0.15
    painted = jnp.einsum("bhwm,bmc->bhwc",
                         inside.astype(jnp.float32), jnp.clip(col, 0, 1))
    img = jnp.clip(img + painted, 0.0, 1.0)
    return img, boxes, classes


def yolo_target(boxes, classes):
    """Rasterize ground truth onto the 10×10×3-anchor grid (YOLOv3 style)."""
    bsz, m, _ = boxes.shape
    tgt = jnp.zeros((bsz, GRID, GRID, NUM_ANCHORS, 5 + NUM_CLASSES))
    cell_y = jnp.clip((boxes[..., 1] * GRID).astype(jnp.int32), 0, GRID - 1)
    cell_x = jnp.clip((boxes[..., 0] * GRID).astype(jnp.int32), 0, GRID - 1)
    # anchor: pick by box area (small/med/large)
    area = boxes[..., 2] * boxes[..., 3]
    anchor = jnp.clip((area / 0.05).astype(jnp.int32), 0, NUM_ANCHORS - 1)
    valid = classes >= 0
    bidx = jnp.arange(bsz)[:, None].repeat(m, 1)
    one_cls = jax.nn.one_hot(jnp.maximum(classes, 0), NUM_CLASSES)
    rows = jnp.concatenate([boxes, jnp.ones((bsz, m, 1)), one_cls], -1)
    rows = rows * valid[..., None]
    tgt = tgt.at[bidx, cell_y, cell_x, anchor].add(rows)
    return jnp.clip(tgt, 0.0, 1.0)
