"""Roofline-driven autotune harness for the W1A8 Pallas kernels.

Per (op, layer shape, accum, device) cell — the structural cells are every
W1A8 layer of the paper's Table 1 network (`models.yolo.yolo_layer_cells`)
— sweep the launch-config space (`bm/bn` for matmul, row blocking for conv
and fused conv+pool, fused-vs-unfused pool routing), measure wall time,
and persist the winner in the committed autotune table
(``benchmarks/results/AUTOTUNE_kernels.json``) that
`kernels.config.resolve` serves at run time. Every candidate is bit-exact
vs the heuristic default (asserted during the sweep) — blocking changes
the launch grid, never the per-row dot operands — so the table is a pure
perf artifact.

Alongside the table, every cell's roofline accounting goes to
``BENCH_kernels.json``: FLOP/byte, the v5e roofline-model time
(`benchmarks/kernel_bench.py` convention: peak 197 Tflops bf16 / 819 GB/s
HBM), the achieved-vs-roofline fraction, and the tuned-vs-default speedup.
On the CPU interpret-mode runner the achieved fraction is a
correctness-trajectory number, not a hardware claim (EXPERIMENTS.md
§Roofline); ``speedup_vs_default`` is the dimensionless, host-portable
metric the CI perf gate protects:

    python -m repro.launch.autotune                    # full sweep
    python -m repro.launch.autotune --bench --reduced --gate-bench

``--bench`` re-measures the committed winners (no sweep) and rewrites
BENCH entries; ``--gate-bench`` fails when a cell's measured speedup
regresses beyond the noise band vs the committed BENCH_kernels.json
(the PR 5 serve-gate mechanics).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"
AUTOTUNE_OUT = RESULTS_DIR / "AUTOTUNE_kernels.json"
BENCH_OUT = RESULTS_DIR / "BENCH_kernels.json"

V5E_FLOPS, V5E_BW = 197e12, 819e9      # kernel_bench.py convention

# Reduced (CI) cells: the cheap half of the table — every op class and
# both accum modes stay covered, keys identical to the full table's.
REDUCED_MAX_H = 40


# ---------------------------------------------------------------------------
# Cells + candidates
# ---------------------------------------------------------------------------

def yolo_cells(batch: int = 1) -> list:
    """Deduped structural cells [(op, dims)] over the YOLO layers."""
    from repro.models.yolo import yolo_layer_cells
    seen, cells = set(), []
    for _, op, dims in yolo_layer_cells(batch):
        if (op, dims) not in seen:
            seen.add((op, dims))
            cells.append((op, dims))
    return cells


def _divisors_leq(n: int, cap: int) -> list:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def candidates(op: str, dims, accum: str) -> list:
    """Candidate KernelConfigs for one cell (always includes the heuristic
    default as candidate 0). bk stays at the heuristic pick so every
    matmul candidate accumulates over the same K blocking — bit-exactness
    vs the default is by construction, and the sweep asserts it anyway."""
    from repro.kernels.config import KernelConfig
    out = []
    if op == "matmul":
        m, k, n = dims
        base = KernelConfig(op=op, accum=accum, out_step=1.0)
        out.append(base)
        bms = sorted({8, 32, 128, 256, min(512, max(8, m // 8 * 8))})
        bns = sorted({128, 256})
        for bm in bms:
            for bn in bns:
                out.append(base.replace(bm=bm, bn=bn))
    else:
        h = dims[0] if op == "conv3x3" else dims[0] // 2
        base = KernelConfig(op=op, accum=accum, out_step=1.0)
        rows_opts = _divisors_leq(h, 16)
        if op == "conv3x3_pool":
            # both accum modes sweep both pool routes: the fused kernel has
            # dot AND popcount datapaths (kernels/w1a8_conv/fused_pool.py)
            out.append(base)        # dataclass default: fused=True
            for fused in (True, False):
                for r in rows_opts:
                    out.append(base.replace(fused=fused, rows=r))
        else:
            out.append(base)
            for r in rows_opts:
                out.append(base.replace(rows=r))
    # dedup, keep first occurrence (the default stays candidate 0)
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def _cand_key(cfg) -> str:
    return json.dumps(cfg.to_dict(), sort_keys=True)


def select_winner(measurements: list) -> tuple:
    """(t_us, config) winner from [(t_us, config)] — deterministic: ties on
    time break on the canonical JSON of the config."""
    return min(measurements, key=lambda m: (m[0], _cand_key(m[1])))


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _operands(op: str, dims, seed: int = 0):
    """Seeded canonical operands for one cell. The activation step is
    uniform (per-tensor) so the same operands serve both accum modes and
    the dot/popcount outputs are directly comparable (bit-exact)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.w1a8_conv import ops as conv_ops
    from repro.kernels.w1a8_matmul import ops as mm_ops
    rng = np.random.default_rng(seed)
    if op == "matmul":
        m, k, n = dims
        a = jnp.asarray(rng.integers(0, 256, (m, k), np.uint8))
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        wp = mm_ops.w1a8_pack_weights(w)
        mul = jnp.full((k,), 0.05, jnp.float32)
        div = jnp.asarray(rng.uniform(0.5, 2.0, (n,)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
        return a, wp, mul, div, b, {"k": k}
    h, w_, cin, cout = dims
    a = jnp.asarray(rng.integers(0, 256, (1, h, w_, cin), np.uint8))
    w = jnp.asarray(rng.standard_normal((3, 3, cin, cout)), jnp.float32)
    wp = conv_ops.conv_pack_weights(w)
    mul = jnp.full((cin,), 0.05, jnp.float32)
    div = jnp.asarray(rng.uniform(0.5, 2.0, (cout,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((cout,)), jnp.float32)
    return a, wp, mul, div, b, {"cin": cin}


def _call(op: str, operands, cfg):
    from repro.kernels.w1a8_conv import ops as conv_ops
    from repro.kernels.w1a8_matmul import ops as mm_ops
    a, wp, mul, div, b, kw = operands
    fn = {"matmul": mm_ops.w1a8_matmul,
          "conv3x3": conv_ops.w1a8_conv3x3,
          "conv3x3_pool": conv_ops.w1a8_conv3x3_pool}[op]
    return fn(a, wp, mul, div, b, config=cfg, **kw)


def time_config(op: str, operands, cfg, iters: int = 3) -> float:
    """Min-of-iters wall µs after one warmup/compile call."""
    import jax
    jax.block_until_ready(_call(op, operands, cfg))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(_call(op, operands, cfg))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def time_pair(op: str, operands, cfg_a, cfg_b, iters: int = 5):
    """Min-of-iters µs for two configs with *interleaved* iterations.

    Timing each config in its own back-to-back block lets any transient
    host load land entirely on one side and corrupt the ratio; alternating
    a/b per iteration exposes both configs to the same conditions, and
    min-of-iters then extracts each one's clean run. This is what the CI
    perf gate compares, so the ratio's stability matters more than either
    absolute time.
    """
    import jax
    jax.block_until_ready(_call(op, operands, cfg_a))
    jax.block_until_ready(_call(op, operands, cfg_b))
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(_call(op, operands, cfg_a))
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(_call(op, operands, cfg_b))
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def roofline(op: str, dims) -> dict:
    """FLOP + byte accounting for one cell (binary MACs at face value,
    uint8 activations, 1-bit packed weights, f32 epilogue params)."""
    if op == "matmul":
        m, k, n = dims
        flops = 2 * m * k * n + 3 * m * n
        nbytes = m * k + k * n / 8 + m * n + 4 * (k + 2 * n)
    else:
        h, w, cin, cout = dims
        flops = 2 * 9 * cin * cout * h * w + 5 * cout * h * w
        out_elems = h * w * cout * (0.25 if op == "conv3x3_pool" else 1.0)
        if op == "conv3x3_pool":
            flops += 3 * cout * (h // 2) * (w // 2)       # 2×2 max = 3 cmp
        nbytes = h * w * cin + 9 * cin * cout / 8 + out_elems \
            + 4 * (cin + 2 * cout)
    t_c, t_m = flops / V5E_FLOPS, nbytes / V5E_BW
    return {"flops": int(flops), "bytes": int(nbytes),
            "flop_per_byte": round(flops / nbytes, 2),
            "t_model_us_v5e": round(max(t_c, t_m) * 1e6, 4),
            "bound": "compute" if t_c >= t_m else "memory"}


# ---------------------------------------------------------------------------
# Sweep / bench drivers
# ---------------------------------------------------------------------------

def sweep_cell(op: str, dims, accum: str, iters: int = 3) -> dict:
    """Sweep one cell; returns its AUTOTUNE entry. Asserts every candidate
    is bit-exact vs the heuristic default before timing it.

    Each candidate is timed *paired + interleaved* against the default
    (`time_pair`) and ranked by its time ratio, not its absolute time —
    separate-block timings let transient host load crown false winners
    whose "speedup" then fails the CI gate on every honest re-measure.
    """
    import numpy as np
    operands = _operands(op, dims)
    cands = candidates(op, dims, accum)
    ref = np.asarray(_call(op, operands, cands[0]))
    measured = [(1.0, cands[0])]
    pair_us = {}
    for cfg in cands[1:]:
        out = np.asarray(_call(op, operands, cfg))
        assert np.array_equal(out, ref), \
            f"candidate not bit-exact: {op}/{dims}/{accum} {cfg}"
        t_def, t_cand = time_pair(op, operands, cands[0], cfg,
                                  max(iters, 5))
        measured.append((t_cand / t_def, cfg))
        pair_us[_cand_key(cfg)] = (t_def, t_cand)
    ratio_best, best = select_winner(measured)
    if _cand_key(best) in pair_us:
        t_default, t_best = pair_us[_cand_key(best)]
    else:  # default won: one config, one timing
        t_default = t_best = time_config(op, operands, cands[0],
                                         max(iters, 5))
    return {"op": op, "dims": list(dims), "accum": accum,
            "config": best.replace(source="table").to_dict(),
            "t_us": round(t_best, 1), "t_default_us": round(t_default, 1),
            "speedup_vs_default": round(1.0 / ratio_best, 3),
            "candidates_tried": len(cands), "iters": iters}


def bench_cell(op: str, dims, accum: str, entry: dict,
               iters: int = 3) -> dict:
    """Re-measure one committed winner vs the heuristic default (no sweep);
    returns its BENCH entry."""
    from repro.kernels.config import KernelConfig
    operands = _operands(op, dims)
    default = candidates(op, dims, accum)[0]
    tuned = KernelConfig.from_dict(entry["config"])
    if tuned == default:  # source is compare=False, so provenance is ignored
        # winner IS the heuristic default: one config, one timing — a second
        # measurement would gate pure run-to-run noise against itself
        t_default = t_tuned = time_config(op, operands, default, iters)
    else:
        t_default, t_tuned = time_pair(op, operands, default, tuned,
                                       max(iters, 5))
    return {"t_us": round(t_tuned, 1), "t_default_us": round(t_default, 1),
            "speedup_vs_default": round(t_default / t_tuned, 3),
            **roofline(op, dims)}


def _bench_from(entry: dict) -> dict:
    op, dims = entry["op"], tuple(entry["dims"])
    return {"t_us": entry["t_us"], "t_default_us": entry["t_default_us"],
            "speedup_vs_default": entry["speedup_vs_default"],
            **roofline(op, dims)}


def _finish_bench(bench: dict, key: str, t_us: float) -> None:
    bench[key]["achieved_frac_v5e"] = round(
        bench[key]["t_model_us_v5e"] / max(t_us, 1e-9), 6)


def _is_reduced(op: str, dims) -> bool:
    return op == "matmul" or dims[0] <= REDUCED_MAX_H


def run(args) -> int:
    from repro.kernels import config as kc
    cells = yolo_cells(batch=args.batch)
    if args.reduced:
        cells = [(op, dims) for op, dims in cells if _is_reduced(op, dims)]
    dev = kc.device_key()
    committed_bench = {}
    if BENCH_OUT.exists():
        committed_bench = json.loads(BENCH_OUT.read_text()).get("entries", {})
    table = {}
    if AUTOTUNE_OUT.exists():
        table = json.loads(AUTOTUNE_OUT.read_text()).get("entries", {})

    bench, failures = {}, []
    for op, dims in cells:
        for accum in ("dot", "popcount"):
            key = kc.shape_key(op, dims, accum, dev)
            if args.bench:
                entry = table.get(key)
                if entry is None:
                    print(f"[skip] no committed entry for {key}")
                    continue
                bench[key] = bench_cell(op, dims, accum, entry,
                                        iters=args.iters)
            else:
                entry = sweep_cell(op, dims, accum, iters=args.iters)
                table[key] = entry
                bench[key] = _bench_from(entry)
            _finish_bench(bench, key, bench[key]["t_us"])
            b = bench[key]
            print(f"{key}: {b['t_us']:.0f}us tuned vs {b['t_default_us']:.0f}"
                  f"us default ({b['speedup_vs_default']:.2f}x), "
                  f"{b['flop_per_byte']:.0f} flop/B {b['bound']}-bound, "
                  f"roofline frac {b['achieved_frac_v5e']:.2e}")
            if args.gate_bench and key in committed_bench:
                band = args.band
                new_s = b["speedup_vs_default"]
                old_s = committed_bench[key]["speedup_vs_default"]
                if new_s < old_s * (1 - band) and new_s < 1 - band:
                    failures.append(
                        f"{key}: speedup_vs_default {new_s:.2f} < committed "
                        f"{old_s:.2f} beyond {band:.0%} noise band")

    if not args.bench:
        AUTOTUNE_OUT.parent.mkdir(parents=True, exist_ok=True)
        AUTOTUNE_OUT.write_text(json.dumps(
            {"version": 1, "device": dev, "entries": table}, indent=1,
            sort_keys=True) + "\n")
        print(f"wrote {AUTOTUNE_OUT} ({len(table)} entries)")
    # like the serve gate: the committed record was read above, so the
    # regenerated file can overwrite it (CI uploads it as an artifact)
    merged = dict(committed_bench)
    merged.update(bench)
    BENCH_OUT.write_text(json.dumps(
        {"version": 1, "device": dev,
         "roofline": {"peak_flops": V5E_FLOPS, "hbm_bw": V5E_BW,
                      "note": "v5e roofline model; measured wall is the "
                              "host runner (interpret mode on CPU) — "
                              "speedup_vs_default is the gated metric"},
         "entries": merged}, indent=1, sort_keys=True) + "\n")
    print(f"wrote {BENCH_OUT} ({len(merged)} entries)")
    if failures:
        print("PERF GATE FAILED:")
        for f in failures:
            print("  " + f)
        return 1
    if args.gate_bench:
        print(f"perf gate OK ({len(bench)} cells within the "
              f"{args.band:.0%} band)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", action="store_true",
                    help="re-measure committed winners only (no sweep)")
    ap.add_argument("--reduced", action="store_true",
                    help=f"cheap cells only (conv h <= {REDUCED_MAX_H} "
                         f"+ matmul) — the CI subset")
    ap.add_argument("--gate-bench", action="store_true",
                    help="fail when a cell's speedup_vs_default regresses "
                         "beyond --band vs committed BENCH_kernels.json")
    ap.add_argument("--band", type=float, default=0.25,
                    help="noise band for --gate-bench (default 0.25)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    raise SystemExit(run(args))


if __name__ == "__main__":
    main()
