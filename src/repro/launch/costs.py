"""Decomposed roofline measurement (§Roofline).

XLA's cost analysis reports **per-device** FLOPs/bytes and counts while-loop
(scan) bodies **once** (calibrated in EXPERIMENTS.md §Dry-run). A full
train_step therefore under-reports by the trip counts. Instead we compile
the program's repeating units separately and assemble:

  train:  microbatches × [ stages × C(stage fwd+bwd) + C(embed+head fwd+bwd) ]
          + C(optimizer update)
  prefill: stages × C(stage fwd) + C(embed+head fwd)
  decode:  stages × C(decode stage) + C(embed+head fwd)

Each unit is compiled under the production mesh with the real shardings, so
its HLO contains the real collectives; collective bytes scale by the same
trip counts. Remat is *not* applied to the measured stage (the assembled
backward already recomputes nothing) — the full module uses remat, so the
assembled compute term is a lower bound the full program approaches within
the remat factor (reported as `remat_overhead`).
"""
import os  # noqa: E402
import sys  # noqa: E402
if "jax" not in sys.modules and \
        "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
    # only force the 512-device pool on fresh module execution — library
    # imports from a live jax process (tests) must not repoison the count
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.shapes import SHAPES, skip_reason       # noqa: E402
from repro.dist import sharding as shard_rules  # noqa: E402
from repro.launch import dryrun as dr          # noqa: E402
from repro.launch.mesh import HW, make_production_mesh     # noqa: E402
from repro.models.layers import embed, norm, unembed       # noqa: E402
from repro.models.transformer import (ShardCtx, _apply_slot,  # noqa: E402
                                      init_lm_params)
from repro.optim import adafactor, adamw       # noqa: E402
from repro.serve import engine as serve_engine  # noqa: E402
from repro.serve.packed import deploy_lm       # noqa: E402


def _cost_of(jitted, *args):
    from repro.compat import cost_analysis_dict
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    coll = dr.parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _scale(unit: dict, trips: int) -> dict:
    coll = {k: (v * trips if isinstance(v, (int, float)) else v)
            for k, v in unit["coll"].items()}
    return {"flops": unit["flops"] * trips, "bytes": unit["bytes"] * trips,
            "coll": coll}


def _merge(parts) -> dict:
    tot = {"flops": 0.0, "bytes": 0.0,
           "coll": {k: 0 for k in ("all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute")}}
    for p in parts:
        tot["flops"] += p["flops"]
        tot["bytes"] += p["bytes"]
        for k in tot["coll"]:
            tot["coll"][k] += p["coll"].get(k, 0)
    return tot


def _slot_slice_sds(slots_sds):
    """Drop the leading stage dim from the stacked slot ShapeDtypeStructs."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), slots_sds)


def _slot_shardings(slot_sds, cfg, mesh):
    return shard_rules.tree_shardings(slot_sds, cfg, mesh)


def _stage_fn(cfg, ctx, mode):
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.period)]

    def stage(slots, x):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        for i, (mk, fk) in enumerate(kinds):
            x = _apply_slot(slots[i], cfg, x, mixer_kind=mk, ffn_kind=fk,
                            mode=mode, positions=positions, ctx=ctx)
        return x
    return stage


def _top_fn(cfg, mode):
    """Embedding + final-norm + LM head (+loss in train) on a (B,S) batch."""
    def top(embed_p, norm_p, tokens, labels):
        x = embed(embed_p, tokens)
        logits = unembed(embed_p, cfg, norm(norm_p, x, cfg.norm_kind))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[..., None],
                                             -1))
    return top


def measure_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 microbatches: int = 8, variant: dict = None) -> dict:
    """variant (§Perf hillclimb knobs): {"flash_block": int,
    "cache_seq_shard": bool, "packed": bool, "microbatches": int}."""
    variant = variant or {}
    microbatches = variant.get("microbatches", microbatches)
    cfg = configs.get_config(arch)
    import dataclasses as _dc
    cfg_updates = {}
    for key in ("flash_block", "pad_heads_to", "capacity_factor"):
        if key in variant:
            cfg_updates[key] = variant[key]
    if variant.get("flat_head"):
        cfg_updates["flat_head_attn"] = True
    if cfg_updates:
        cfg = _dc.replace(cfg, **cfg_updates)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    dp = shard_rules.dp_axes(mesh)
    stages = cfg.num_layers // cfg.period
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names)}
    skip = skip_reason(arch, shape_name)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec
    if spec.kind == "train":
        rec["pipeline_bubble"] = dr.pipeline_bubble_record(
            cfg, microbatches=microbatches)

    dtype = jnp.bfloat16 if (arch in dr.BIG or spec.kind != "train") \
        else jnp.float32
    params_sds = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg, dtype))
    long_ctx = spec.global_batch < dr._axsize(mesh, dp)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp if not long_ctx else (),
                   tp_axis="model",
                   ep_axis="data" if cfg.num_experts else None,
                   a2a_quant=bool(variant.get("a2a_quant", False)))
    mode = "w1a8_train" if spec.kind == "train" else "w1a8_eval"
    with mesh:
        if spec.kind == "train":
            b_mb = spec.global_batch // microbatches
            s = spec.seq_len
            x_sds = jax.ShapeDtypeStruct((b_mb, s, cfg.d_model), dtype)
            x_sh = NamedSharding(mesh, P(dp, None, None))
            slots_sds = _slot_slice_sds(params_sds["slots"])
            slots_sh = _slot_shardings(slots_sds, cfg, mesh)
            stage = _stage_fn(cfg, ctx, mode)

            def stage_vjp(slots, x, ct):
                _, f = jax.vjp(stage, slots, x)
                return f(ct)

            c_stage = _cost_of(
                jax.jit(stage_vjp, in_shardings=(slots_sh, x_sh, x_sh)),
                slots_sds, x_sds, x_sds)

            top = _top_fn(cfg, mode)

            def top_vjp(ep_, np_, tokens, labels):
                (loss, f) = jax.vjp(
                    lambda e, n: top(e, n, tokens, labels), ep_, np_)
                return f(jnp.ones_like(loss))

            tok_sds = jax.ShapeDtypeStruct((b_mb, s), jnp.int32)
            tok_sh = NamedSharding(mesh, P(dp, None))
            ep_sds = _sds_of(params_sds["embed"])
            np_sds = _sds_of(params_sds["final_norm"])
            ep_sh = shard_rules.tree_shardings(ep_sds, cfg, mesh)
            np_sh = shard_rules.tree_shardings(np_sds, cfg, mesh)
            c_top = _cost_of(
                jax.jit(top_vjp,
                        in_shardings=(ep_sh, np_sh, tok_sh, tok_sh)),
                ep_sds, np_sds, tok_sds, tok_sds)

            opt = adafactor(1e-3) if arch in dr.BIG else adamw(1e-3)
            opt_sds = jax.eval_shape(opt[0], params_sds)
            p_sh = shard_rules.tree_shardings(params_sds, cfg, mesh)
            o_sh = shard_rules.tree_shardings(opt_sds, cfg, mesh)
            c_opt = _cost_of(
                jax.jit(lambda g, s_, p: opt[1](g, s_, p),
                        in_shardings=(p_sh, o_sh, p_sh)),
                params_sds, opt_sds, params_sds)

            total = _merge([_scale(c_stage, stages * microbatches),
                            _scale(c_top, microbatches), c_opt])
            rec["parts"] = {"stage_fwdbwd": c_stage, "top_fwdbwd": c_top,
                            "optimizer": c_opt,
                            "trips": {"stage": stages * microbatches,
                                      "top": microbatches}}
        elif spec.kind == "prefill":
            b, s = spec.global_batch, spec.seq_len
            if cfg.w1a8_body and variant.get("packed", True):
                params_sds = jax.eval_shape(deploy_lm, params_sds)
            x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
            x_sh = NamedSharding(mesh, P(dp, None, None))
            slots_sds = _slot_slice_sds(params_sds["slots"])
            slots_sh = _slot_shardings(slots_sds, cfg, mesh)
            stage = _stage_fn(cfg, ctx, mode)
            c_stage = _cost_of(
                jax.jit(stage, in_shardings=(slots_sh, x_sh)),
                slots_sds, x_sds)
            c_top = _top_cost_fwd(cfg, params_sds, mesh, dp, b, s, mode)
            total = _merge([_scale(c_stage, stages), c_top])
            rec["parts"] = {"stage_fwd": c_stage, "top_fwd": c_top,
                            "trips": {"stage": stages}}
        else:  # decode
            b = spec.global_batch
            if cfg.w1a8_body and variant.get("packed", True):
                params_sds = jax.eval_shape(deploy_lm, params_sds)
            cache_sds = jax.eval_shape(
                lambda: serve_engine.init_cache(cfg, b, spec.seq_len,
                                                jnp.bfloat16))
            cache_sh = dr._cache_shardings(
                cache_sds, mesh, cfg, dp=dp, long_ctx=long_ctx,
                seq_shard_fallback=variant.get("cache_seq_shard", False))
            slots_sds = _slot_slice_sds(params_sds["slots"])
            slots_sh = _slot_shardings(slots_sds, cfg, mesh)
            cslots_sds = _slot_slice_sds(cache_sds["slots"])
            cslots_sh = _slot_slice_shardings(cache_sh["slots"])
            x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
            bspec = dp if not long_ctx else None
            x_sh = NamedSharding(mesh, P(bspec, None, None))
            pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
            pos_sh = NamedSharding(mesh, P(bspec))
            dstage = _decode_stage_fn(cfg, ctx, "w1a8_eval")
            c_stage = _cost_of(
                jax.jit(dstage, in_shardings=(slots_sh, cslots_sh, x_sh,
                                              pos_sh)),
                slots_sds, cslots_sds, x_sds, pos_sds)
            c_top = _top_cost_fwd(cfg, params_sds, mesh, dp, b, 1,
                                  "w1a8_eval", bspec=bspec)
            total = _merge([_scale(c_stage, stages), c_top])
            rec["parts"] = {"stage_decode": c_stage, "top_fwd": c_top,
                            "trips": {"stage": stages}}

    cw = dr.wire_bytes(total["coll"], n_chips)
    ana_bytes = analytic_bytes(cfg, spec, params_sds, n_chips,
                               microbatches=microbatches,
                               cache_seq_shard=variant.get("cache_seq_shard",
                                                           False))
    rec["totals"] = {"flops_per_device": total["flops"],
                     "bytes_per_device_measured_unfused": total["bytes"],
                     "bytes_per_device_analytic": ana_bytes,
                     "collective_wire_bytes": cw}
    # per-device terms (cost analysis is per-device — calibrated).
    # memory: the measured "bytes accessed" comes from UNFUSED CPU HLO and
    # over-counts intermediates ~5-20×; the analytic model (weights+state
    # traffic + stage-boundary activations) is the roofline term, with the
    # measured value kept as an upper bound.
    t_comp = total["flops"] / HW["peak_flops_bf16"]
    t_mem = ana_bytes / HW["hbm_bw"]
    t_mem_upper = total["bytes"] / HW["hbm_bw"]
    t_coll = cw / HW["ici_bw"]
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = dr.model_flops(arch, shape_name) / n_chips
    rec["roofline"] = {
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_upper_s": t_mem_upper, "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / total["flops"] if total["flops"] else None,
        "step_time_bound_s": max(t_comp, t_mem, t_coll),
        "roofline_fraction": (mf / HW["peak_flops_bf16"]) /
                             max(t_comp, t_mem, t_coll)
                             if max(t_comp, t_mem, t_coll) > 0 else None,
    }
    rec["status"] = "ok"
    return rec


def analytic_bytes(cfg, spec, params_sds, n_chips, *,
                   microbatches: int = 8,
                   cache_seq_shard: bool = False) -> float:
    """Per-device HBM traffic model (fused-execution napkin roofline).

    train:   3 weight passes/microbatch (fwd, remat-fwd, bwd) + grad
             accumulation r/w (f32) + optimizer state r/w + residual-stream
             activations at stage boundaries (×4 traversals).
    prefill: 1 weight pass + activations.
    decode:  1 weight pass + KV/SSM cache read+write (the dominant term; with
             packed W1A8 the weight pass is 1 bit/weight — the §Perf lever).
    """
    leaves = jax.tree_util.tree_leaves(params_sds)
    p_bytes = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                  for l in leaves) / n_chips
    p_count = sum(int(np.prod(l.shape)) for l in leaves) / n_chips
    d = cfg.d_model
    act_bytes = 2  # bf16 residual stream
    stages = cfg.num_layers // cfg.period
    if spec.kind == "train":
        # tokens shard over dp axes only (model axis = 16 in both meshes)
        tok_pd = spec.global_batch * spec.seq_len / (n_chips / 16)
        weights = 3 * microbatches * p_bytes
        grads = 2 * microbatches * p_count * 4
        opt = 5 * p_count * 4
        acts = 4 * stages * tok_pd * d * act_bytes
        return weights + grads + opt + acts
    if spec.kind == "prefill":
        tok_pd = spec.global_batch * spec.seq_len / (n_chips / 16)
        return p_bytes + 4 * stages * tok_pd * d * act_bytes
    # decode
    cache = jax.eval_shape(
        lambda: serve_engine.init_cache(cfg, spec.global_batch,
                                        spec.seq_len, jnp.bfloat16))
    c_leaves = jax.tree_util.tree_leaves(cache)
    c_total = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                  for l in c_leaves)
    # cache shards over dp (batch) when divisible, else over data (seq);
    # kv-head dim additionally over model when divisible.
    dp_size = n_chips / 16                      # data(+pod) axes
    kv_shard = 16 if (cfg.num_kv_heads % 16 == 0 or cache_seq_shard) else 1
    c_pd = c_total / min(dp_size * kv_shard, n_chips)
    return p_bytes + 2 * c_pd


def _sds_of(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _slot_slice_shardings(cache_sh_slots):
    """Drop the stage dim from cache shardings (first axis of each spec)."""
    def conv(ns):
        spec = list(ns.spec) + [None] * 8
        return NamedSharding(ns.mesh, P(*spec[1:len(ns.spec)]))
    return jax.tree_util.tree_map(
        conv, cache_sh_slots,
        is_leaf=lambda x: isinstance(x, NamedSharding))


def _top_cost_fwd(cfg, params_sds, mesh, dp, b, s, mode, bspec="unset"):
    if bspec == "unset":
        bspec = dp
    top = _top_fn(cfg, mode)
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_sh = NamedSharding(mesh, P(bspec, None))
    ep_sds = _sds_of(params_sds["embed"])
    np_sds = _sds_of(params_sds["final_norm"])
    ep_sh = shard_rules.tree_shardings(ep_sds, cfg, mesh)
    np_sh = shard_rules.tree_shardings(np_sds, cfg, mesh)
    return _cost_of(
        jax.jit(lambda e, n, t: top(e, n, t, t),
                in_shardings=(ep_sh, np_sh, tok_sh)),
        ep_sds, np_sds, tok_sds)


def _decode_stage_fn(cfg, ctx, mode):
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.period)]

    def dstage(slots, caches, x, pos):
        from repro.models.layers import mlp
        from repro.models.transformer import _apply_moe
        from repro.serve.engine import _attn_decode
        from repro.models import mamba as mb
        for i, (mk, fk) in enumerate(kinds):
            slot, c = slots[i], caches[i]
            h = norm(slot["norm1"], x, cfg.norm_kind)
            if mk.startswith("attn"):
                window = 0
                if mk == "attn_local" or (cfg.sliding_window and
                                          not cfg.local_global):
                    window = cfg.sliding_window
                out, *_ = _attn_decode(slot["attn"], cfg, h, c["k"], c["v"],
                                       c["pos"], pos, mode=mode,
                                       window=window)
            else:
                step_fn = (mb.mamba2_decode_step if cfg.ssm_kind == "mamba2"
                           else mb.mamba1_decode_step)
                out, _ = step_fn(slot["mamba"], cfg, h, c, mode)
            if cfg.post_norms:
                out = norm(slot["post_norm1"], out, cfg.norm_kind)
            x = x + out
            if fk != "none":
                h = norm(slot["norm2"], x, cfg.norm_kind)
                if fk == "moe":
                    out = _apply_moe(slot["moe"], cfg, h, mode, ctx)
                else:
                    out = mlp(slot["mlp"], cfg, h, mode)
                if cfg.post_norms:
                    out = norm(slot["post_norm2"], out, cfg.norm_kind)
                x = x + out
        return x
    return dstage


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=os.path.join(dr.RESULTS_DIR,
                                                  "costs.json"))
    ap.add_argument("--variant", default=None,
                    help="k=v[,k=v] hillclimb knobs, e.g. flash_block=1024")
    args = ap.parse_args()
    variant = {}
    if args.variant:
        for kv in args.variant.split(","):
            k, v = kv.split("=")
            if v.lower() in ("true", "false"):
                variant[k] = v.lower() == "true"
            else:
                variant[k] = int(v)
    archs = list(configs.ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(dr.RESULTS_DIR, exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = [r for r in json.load(f)
                       if r.get("status") in ("ok", "skipped")]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    for arch in archs:
        for shape in shapes:
            if (arch, shape, mesh_name) in done:
                continue
            print(f"=== cost {arch} × {shape} × {mesh_name}", flush=True)
            t0 = time.time()
            try:
                rec = measure_cell(arch, shape, multi_pod=args.multi_pod,
                                   variant=variant)
            except Exception as e:                         # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-1500:]}
            rec["measure_s"] = round(time.time() - t0, 1)
            if variant:
                rec["variant"] = variant
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"    comp={r['t_compute_s']:.4g}s "
                      f"mem={r['t_memory_s']:.4g}s "
                      f"coll={r['t_collective_s']:.4g}s → {r['bottleneck']} "
                      f"(roofline {r['roofline_fraction'] and round(r['roofline_fraction'],3)})",
                      flush=True)
            else:
                print("    " + rec.get("error", rec["status"])[:200],
                      flush=True)


if __name__ == "__main__":
    main()
