"""Fleet traffic harness: ``python -m repro.launch.traffic --mode {model,real}``.

Replays seeded synthetic traffic through the fleet tier (serve.fleet:
Router → replica Schedulers → Autoscaler), two ways:

  model — the pure-python replay: ModelBackend replicas whose step cost is
          calibrated from the committed BENCH_serve.json detect record
          (device batch width = ``slots``, wall cost per tick =
          ``tick_p50_ms``), so SLO accounting runs in scheduler ticks — the
          unit the real fleet shares — at millions of requests per minute
          of harness time. Sweeps steady / diurnal / burst traces at 1, 2
          and 4 fixed replicas plus one autoscaled (1→4) run per trace and
          writes fleet SLO accounting (attainment %, drops by cause,
          replica-count timeline) into benchmarks/results/BENCH_fleet.json.
          Every cell asserts ZERO lost requests (completed + every drop
          cause = submitted).
  real  — the reduced run through actual DetectionBackend replicas (shared
          compiled executable via backend.spawn()): the same seeded request
          stream through a 1-replica fleet and an N-replica fleet must
          complete the SAME request-id set with BIT-EXACT detection
          payloads — routing and scale must never change what a request
          computes.

Traces (per-tick Poisson arrivals from a seeded generator; rates are
relative to a 2-replica fleet's service capacity):
  steady   0.85× reference capacity, constant;
  diurnal  0.85× mean with a ±0.80× two-period sinusoid (trough ~0.05,
           peak ~1.65 — overloads 2 replicas, fits 4);
  burst    0.60× base with ~1/400-per-tick chance of a 25-tick 6× spike.

Request mix: 90% priority 0 (admission deadline 2×SLO, completion deadline
2×SLO — a request admitted at the very edge of its admission window can no
longer finish and is dropped in flight), 10% priority 1 background (no
admission deadline, completion deadline 4×SLO — starved background work
expires instead of completing arbitrarily late). Attainment counts
completions within ``slo_ticks`` end-to-end over ALL submissions.

``--gate-bench`` reads the committed BENCH_fleet.json BEFORE overwriting it
and fails when any model cell's SLO attainment drops below committed ×
0.95 (the replay is deterministic in ticks, so this really gates scheduler
semantics, not machine speed) or loses a request.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

DEFAULT_OUT = "benchmarks/results/BENCH_fleet.json"
SERVE_BENCH = "benchmarks/results/BENCH_serve.json"
TRACES = ("steady", "diurnal", "burst")
FIXED_REPLICAS = (1, 2, 4)
REF_REPLICAS = 2          # trace rates are sized against this fleet


def calibrate(serve_bench: str) -> dict:
    """Replica step-cost model from the committed detect serving record.

    The committed detect config runs a K-deep dispatch window (batch t
    computes while later batches stage), so the model replica mirrors it:
    depth×width slots, width admissions per tick, 2-tick service — steady
    throughput is width requests per tick and every request's latency
    includes the pipeline's extra in-flight ticks, same as the real
    backend."""
    width, tick_ms, depth = 2, 200.0, 2
    p = pathlib.Path(serve_bench)
    if p.exists():
        try:
            rec = json.loads(p.read_text()).get("detect", {})
            width = int(rec.get("slots", width))
            tick_ms = float(rec.get("tick_p50_ms", tick_ms))
            depth = max(int(rec.get("depth", depth)), 1)
        except (json.JSONDecodeError, TypeError, ValueError):
            pass
    return {"width": width, "tick_ms": tick_ms, "service_ticks": 2,
            "depth": depth, "source": serve_bench}


def gen_trace(kind: str, n_requests: int, ref_rate: float,
              rng: np.random.Generator) -> np.ndarray:
    """Per-tick arrival counts; Σ ≈ n_requests."""
    if kind == "steady":
        mean = 0.85 * ref_rate
        ticks = max(int(round(n_requests / mean)), 1)
        rate = np.full(ticks, mean)
    elif kind == "diurnal":
        mean = 0.85 * ref_rate
        ticks = max(int(round(n_requests / mean)), 1)
        t = np.arange(ticks)
        rate = ref_rate * (0.85 + 0.80 * np.sin(2 * np.pi * 2 * t / ticks))
        rate = np.clip(rate, 0.05, None)
    elif kind == "burst":
        base, spike_p, spike_len, spike_mult = 0.60, 1 / 400, 25, 6.0
        mean = base * ref_rate * (1 + spike_p * spike_len * spike_mult)
        ticks = max(int(round(n_requests / mean)), 1)
        rate = np.full(ticks, base * ref_rate)
        starts = np.flatnonzero(rng.random(ticks) < spike_p)
        for s in starts:
            rate[s:s + spike_len] = spike_mult * base * ref_rate
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    return rng.poisson(rate).astype(np.int64)


def replay_model(kind: str, n_replicas: int, *, n_requests: int, seed: int,
                 cal: dict, slo_ticks: int, autoscale: bool = False,
                 max_replicas: int = 4) -> dict:
    from repro.serve.api import SamplingParams, ServeRequest
    from repro.serve.fleet import (Autoscaler, AutoscalerConfig,
                                   FleetMetrics, ModelBackend, Router)

    width, service = cal["width"], cal["service_ticks"]
    depth = max(int(cal.get("depth", 2 if cal.get("overlap") else 1)), 1)
    # per-replica steady throughput: capacity / service ticks
    ref_rate = REF_REPLICAS * depth * width / service
    # str hash is per-process randomized; the trace seed must not be
    rng = np.random.default_rng([seed, TRACES.index(kind)])
    arrivals = gen_trace(kind, n_requests, ref_rate, rng)
    total = int(arrivals.sum())
    background = rng.random(total) < 0.10

    scaler = None
    if autoscale:
        scaler = Autoscaler(AutoscalerConfig(
            min_replicas=n_replicas, max_replicas=max_replicas,
            window=8, queue_high=2.0, occ_low=0.35,
            cooldown_up=8, cooldown_down=48))
    metrics = FleetMetrics(slo_ticks=slo_ticks)
    # queue bound sized so waits can overrun the admission deadline: both
    # expiry causes (not just rejection) show up in the drop accounting
    router = Router(lambda: ModelBackend(width, service, depth=depth),
                    replicas=n_replicas, max_queue=4 * width * slo_ticks,
                    autoscaler=scaler, metrics=metrics)
    sp = SamplingParams()              # shared: requests carry no LM state
    rid = 0
    t0 = time.perf_counter()
    for n_arr in arrivals:
        for _ in range(int(n_arr)):
            if background[rid]:
                req = ServeRequest(rid=rid, sampling=sp, priority=1,
                                   completion_deadline_ticks=4 * slo_ticks)
            else:
                req = ServeRequest(rid=rid, sampling=sp,
                                   deadline_ticks=2 * slo_ticks,
                                   completion_deadline_ticks=2 * slo_ticks)
            router.submit(req)
            rid += 1
        router.tick()
    router.drain()
    elapsed = time.perf_counter() - t0
    assert rid == total
    assert metrics.lost == 0, (kind, n_replicas, metrics.summary())
    summary = metrics.summary()
    n_events = len(summary.pop("scale_events"))
    return {"trace": kind, "replicas": n_replicas,
            "autoscale": bool(autoscale),
            "trace_ticks": int(len(arrivals)),
            "replay_seconds": round(elapsed, 3),
            "n_scale_events": n_events,
            "simulated_wall_s": round(summary["ticks"] * cal["tick_ms"]
                                      / 1e3, 1),
            **summary}


def run_model(args) -> dict:
    cal = calibrate(args.serve_bench)
    slo_ticks = max(int(round(args.slo_ms / cal["tick_ms"])), 4)
    record = {"config": {**cal, "slo_ms": args.slo_ms,
                         "slo_ticks": slo_ticks,
                         "requests_per_cell": args.requests,
                         "seed": args.seed}}
    total = 0
    t0 = time.perf_counter()
    for kind in TRACES:
        cells = {}
        for n in FIXED_REPLICAS:
            cell = replay_model(kind, n, n_requests=args.requests,
                                seed=args.seed, cal=cal, slo_ticks=slo_ticks)
            cells[f"replicas_{n}"] = cell
            total += cell["requests_submitted"]
            print(f"[model] {kind:8s} x{n}: "
                  f"{cell['requests_submitted']} reqs, "
                  f"attainment {cell['slo_attainment']:.3f}, drops "
                  f"{cell['drops_by_cause']} ({cell['replay_seconds']}s)")
        cell = replay_model(kind, 1, n_requests=args.requests,
                            seed=args.seed, cal=cal, slo_ticks=slo_ticks,
                            autoscale=True, max_replicas=4)
        cells["autoscale_1to4"] = cell
        total += cell["requests_submitted"]
        print(f"[model] {kind:8s} auto(1→4): attainment "
              f"{cell['slo_attainment']:.3f}, replicas "
              f"{cell['replicas_min']}→{cell['replicas_max']} "
              f"({cell['n_scale_events']} scale events, "
              f"{cell['replay_seconds']}s)")
        record[kind] = cells
    elapsed = time.perf_counter() - t0
    record["total_requests"] = total
    record["harness_seconds"] = round(elapsed, 1)
    print(f"[model] replayed {total} requests in {elapsed:.1f}s")
    if args.max_seconds:
        # 10x the per-cell request count: at the default --requests 100000
        # this is the acceptance floor of 1e6 total replayed requests, and
        # it scales down for reduced smoke runs instead of always demanding
        # the full million.
        floor = 10 * args.requests
        assert total >= floor, \
            f"replayed only {total} requests (need >= {floor})"
        assert elapsed < args.max_seconds, \
            f"replay took {elapsed:.1f}s (budget {args.max_seconds}s)"
    return record


# ---------------------------------------------------------------------------
# Real mode: reduced trace through actual DetectionBackend replicas
# ---------------------------------------------------------------------------

def _image(seed: int, rid: int, size: int) -> np.ndarray:
    """Deterministic per-rid uint8 image — distinct per request, generated
    lazily so a 2k-request stream never holds 2k images live."""
    rng = np.random.default_rng([seed, rid])
    return rng.integers(0, 256, (size, size, 3), np.uint8)


def _run_real_fleet(template, n_replicas: int, n_req: int, seed: int,
                    size: int) -> tuple:
    from repro.serve.api import ServeRequest
    from repro.serve.fleet import FleetMetrics, Router

    metrics = FleetMetrics()
    router = Router(template.spawn, replicas=n_replicas, keep_results=True,
                    metrics=metrics)
    width = template.admit_width
    rid = 0
    t0 = time.perf_counter()
    while rid < n_req or router.busy:
        # paced submission: keep ~2 batches queued per replica so the
        # admission pipeline stays full without holding the stream's
        # images live all at once
        while rid < n_req and router.total_queued() < 2 * n_replicas * width:
            router.submit(ServeRequest(rid=rid,
                                       image=_image(seed, rid, size)))
            rid += 1
        router.tick()
    elapsed = time.perf_counter() - t0
    assert metrics.lost == 0 and metrics.dropped == 0, metrics.summary()
    payloads = {r.rid: r.detections for r in router.results}
    assert len(payloads) == n_req
    return payloads, metrics.summary(), elapsed


def run_real(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.models import yolo
    from repro.serve import DetectionBackend

    n_req = args.requests
    size = yolo.INPUT_SIZE
    _, art = yolo.build_detector(
        jax.random.PRNGKey(args.seed),
        jnp.asarray(_image(args.seed, 0, size)[None], jnp.float32) / 256.0,
        profile=args.profile)
    template = DetectionBackend(art, slots=args.slots, depth=2,
                                device_nms=True, profile=args.profile)
    template.warmup()                  # one compile covers every spawn()

    single, single_summary, t1 = _run_real_fleet(template, 1, n_req,
                                                 args.seed, size)
    fleet, fleet_summary, tn = _run_real_fleet(template, args.replicas,
                                               n_req, args.seed, size)
    assert set(fleet) == set(single) == set(range(n_req)), \
        "fleet completed a different request-id set than single-replica"
    for rid in range(n_req):
        a, b = single[rid], fleet[rid]
        assert a.keys() == b.keys(), rid
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), \
                f"rid {rid}: payload field {k!r} diverged across fleets"
    print(f"[real] {n_req} requests: 1-replica {n_req/t1:.2f} img/s, "
          f"{args.replicas}-replica {n_req/tn:.2f} img/s; completed sets "
          f"equal, payloads bit-exact")
    return {"requests": n_req, "replicas": args.replicas,
            "slots": args.slots, "profile": args.profile,
            "equivalence": "completed-id sets equal, payloads bit-exact "
                           "vs 1-replica fleet",
            "img_per_s_single": n_req / t1,
            "img_per_s_fleet": n_req / tn,
            "fleet": fleet_summary, "single": single_summary}


# ---------------------------------------------------------------------------

def _write_bench(path: str, key: str, record: dict) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if p.exists():
        try:
            data = json.loads(p.read_text())
        except json.JSONDecodeError:
            data = {}
    data[key] = record
    p.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    print(f"[bench] wrote {path} [{key}]")


def _gate(committed: dict, record: dict) -> None:
    """Fail when a model cell lost a request or its SLO attainment fell
    below committed × 0.95."""
    for kind in TRACES:
        for cell_name, cell in record.get(kind, {}).items():
            assert cell["requests_lost"] == 0, (kind, cell_name)
            old = committed.get(kind, {}).get(cell_name, {})
            floor = old.get("slo_attainment")
            if floor is None:
                continue
            got = cell["slo_attainment"]
            assert got >= floor * 0.95 - 1e-12, \
                (f"{kind}/{cell_name}: attainment {got:.4f} < committed "
                 f"{floor:.4f} x 0.95")
            print(f"[gate] {kind}/{cell_name}: {got:.4f} >= "
                  f"{floor:.4f} x 0.95 OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("model", "real"), default="model")
    ap.add_argument("--requests", type=int, default=None,
                    help="model: requests PER CELL (default 100000, 12 "
                         "cells); real: total requests (default 2048)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet width for the real run")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--profile", choices=("tuned", "default", "interpret"),
                    default="tuned")
    ap.add_argument("--slo-ms", type=float, default=5000.0,
                    help="end-to-end completion SLO (converted to ticks "
                         "via the calibrated tick cost)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serve-bench", default=SERVE_BENCH)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--max-seconds", type=float, default=0.0,
                    help="model: assert >=1e6 requests replayed under this "
                         "wall budget (0 = no assert)")
    ap.add_argument("--gate-bench", action="store_true",
                    help="model: fail when a cell loses requests or SLO "
                         "attainment < committed x 0.95")
    args = ap.parse_args()
    if args.requests is None:
        args.requests = 100_000 if args.mode == "model" else 2048

    committed = {}
    if args.gate_bench:
        p = pathlib.Path(args.out)
        if p.exists():
            try:
                committed = json.loads(p.read_text()).get("model", {})
            except json.JSONDecodeError:
                committed = {}

    if args.mode == "model":
        record = run_model(args)
        if args.gate_bench:
            if committed:
                _gate(committed, record)
            else:
                print(f"[gate] no committed model record in {args.out} — "
                      f"gate records, next run enforces")
        _write_bench(args.out, "model", record)
    else:
        record = run_real(args)
        _write_bench(args.out, "real", record)


if __name__ == "__main__":
    main()
