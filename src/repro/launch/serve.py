"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the slot-based continuous-batching engine over a synthetic request
stream; --packed deploys 1-bit W1A8 weights (the paper's deployed form).
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--packed", action="store_true",
                    help="deploy 1-bit packed W1A8 weights")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.models.transformer import init_lm_params
    from repro.serve import ServeEngine, deploy_lm, packed_param_bytes
    from repro.serve.batching import Request

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
    mode = "float"
    if args.packed:
        params = deploy_lm(params)
        acct = packed_param_bytes(params)
        print(f"[packed] {acct['packed_bytes']/1e6:.1f} MB "
              f"(bf16-equivalent {acct['bf16_equivalent_bytes']/1e6:.1f} MB, "
              f"{acct['ratio']:.1f}x smaller)")
        mode = "w1a8_eval"

    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      mode=mode, temperature=args.temperature)
    reqs = [Request(rid=i, prompt=[2 + i, 11, 7 + i % 3], max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    eng.run(list(reqs))
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} → {r.out[:10]}...")


if __name__ == "__main__":
    main()
