"""Serving launcher: ``python -m repro.launch.serve --workload {lm,detect}``.

Drives the serve-v3 Scheduler over a synthetic request stream against one of
the two backends:

  lm      — continuous-batched decode of an LM arch (--packed deploys 1-bit
            W1A8 weights, the paper's deployed form, and decodes with them).
            Runs the host-checked termination path AND the device-side
            done-mask path over the same request stream and records both —
            the done-mask run is the headline record, the host-checked run
            lands under ``baseline_host_check`` (token sequences asserted
            identical).
  detect  — the paper's deployed artifact: batched image requests through
            the packed-W1A8 YOLO Pallas path + NMS, with a core.verify
            alignment check against the float reference. Sweeps the K-deep
            dispatch window over K ∈ {1, 2, 4, 8} on the device-NMS wire
            (one shared executable via spawn(depth=K)), asserting every
            K ≥ 2 run bit-exact vs the K=1 single-shot payloads and
            completion in dispatch order; the HEADLINE record is the
            ``--depth`` run, with the full per-K saturation curve under
            ``depth_sweep``. Also runs single-shot and depth-2 raw-wire
            baselines — asserting the device-NMS detection set matches the
            raw-wire path and shrinks per-sync bytes ≥ 10×. ``--burst 4x``
            submits the whole stream as one burst (4× the slot width)
            through the bounded wait queue and asserts zero drops and ≤ 1
            host sync per tick. ``--replicas N`` (and ``--autoscale``)
            additionally routes the same stream through a fleet Router of
            N spawned replicas (serve.fleet) and asserts the payloads stay
            bit-exact vs the single-scheduler run.
  multires — bucketed multi-resolution admission: one detector artifact
            serving ``--buckets`` (default 256,320) image sizes through
            ONE scheduler, per-bucket batches packed off
            `ServeRequest.image_shape`, one fixed-width executable per
            bucket sharing packed weights. Asserts each bucket's raw head
            bit-exact vs its single-resolution reference run, then records
            the per-bucket × per-K saturation table on the device-NMS
            wire.
  compose — the detect→LM pipeline (`serve.compose`): detection emissions
            template into an LM prompt ("describe what was detected") and
            re-admit to the LMBackend on the same tick loop. Asserts zero
            lost / duplicated requests and hand-off determinism.

Writes/merges throughput + latency + occupancy + host-sync numbers into
``benchmarks/results/BENCH_serve.json`` (methodology: EXPERIMENTS.md §Serve).
``--gate-bench`` reads the committed record for the workload BEFORE
overwriting it and fails when the new ``host_sync_bytes_per_tick`` regresses
above committed × 1.05 (lm, detect) or ``img_per_s`` at the chosen K drops
below committed × 0.95 (detect, multires) — the CI guards on the serving
wire and the dispatch pipeline.
"""
from __future__ import annotations

import argparse
import json
import pathlib

DEFAULT_OUT = "benchmarks/results/BENCH_serve.json"


def _write_bench(path: str, workload: str, record: dict) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if p.exists():
        try:
            data = json.loads(p.read_text())
        except json.JSONDecodeError:
            data = {}
    data[workload] = record
    p.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    print(f"[bench] wrote {path} [{workload}]")


def _parse_burst(burst: str, slots: int) -> int:
    """'4x' → 4·slots requests submitted as one burst; '0' → streaming."""
    if not burst:
        return 0
    mult = burst[:-1] if burst.endswith(("x", "X")) else burst
    return int(mult) * slots


def run_lm(args) -> dict:
    import jax
    from repro import configs
    from repro.models.transformer import init_lm_params
    from repro.serve import (LMBackend, SamplingParams, Scheduler,
                             ServeRequest, deploy_lm, packed_param_bytes)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
    mode = "float"
    if args.packed:
        params = deploy_lm(params)
        acct = packed_param_bytes(params)
        print(f"[packed] {acct['packed_bytes']/1e6:.1f} MB "
              f"(bf16-equivalent {acct['bf16_equivalent_bytes']/1e6:.1f} MB, "
              f"{acct['ratio']:.1f}x smaller)")
        mode = "w1a8_eval"

    sp = SamplingParams(max_new=args.max_new, temperature=args.temperature,
                        stop_tokens=tuple(args.stop_token))

    def serve(done_mask: bool):
        backend = LMBackend(cfg, params, slots=args.slots,
                            max_len=args.max_len, mode=mode, seed=args.seed,
                            done_mask=done_mask)

        def stream():
            return [ServeRequest(rid=i, prompt=[2 + i, 11, 7 + i % 3],
                                 sampling=sp) for i in range(args.requests)]

        # warm pass on a throwaway scheduler compiles this backend's jitted
        # step (and warms the eager prefill ops) so both modes' measured
        # numbers are steady-state — same discipline as detect's warmup().
        # Both modes consume the PRNG stream identically in the warm pass,
        # so the measured token sequences stay comparable across modes.
        Scheduler(backend).run(stream())
        sched = Scheduler(backend)
        results = sched.run(stream())
        return results, sched.metrics.summary()

    host_results, host_summary = serve(done_mask=False)
    dm_results, summary = serve(done_mask=True)
    host_toks = {r.rid: r.tokens for r in host_results}
    dm_toks = {r.rid: r.tokens for r in dm_results}
    assert dm_toks == host_toks, "done-mask decode diverged from host check"
    print(f"served {len(dm_results)} requests, {summary['tokens']} tokens in "
          f"{summary['wall_s']:.2f}s ({summary['tok_per_s']:.1f} tok/s, "
          f"p50 tick {summary['tick_p50_ms']:.1f} ms, "
          f"occupancy {summary['batch_occupancy']:.2f}); "
          f"per-tick sync {summary['host_sync_bytes_per_tick']:.0f} B "
          f"done-mask vs {host_summary['host_sync_bytes_per_tick']:.0f} B "
          f"token-row host-checked")
    for r in dm_results[:3]:
        print(f"  req {r.rid} [{r.finish_reason}]: {r.tokens[:10]}...")
    return {"arch": args.arch, "reduced": args.reduced, "packed": args.packed,
            "slots": args.slots, "max_new": args.max_new,
            "termination": "device_done_mask",
            "sync_wire": "per-slot bool bitmask/tick + bulk tokens at finish",
            **summary,
            "baseline_host_check": {
                "termination": "host_token_check",
                "sync_wire": "token row/tick",
                **host_summary}}


def run_detect(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import verify
    from repro.models import detection, yolo
    from repro.serve import DetectionBackend, Scheduler, ServeRequest

    n_req = 2 if args.reduced else args.requests
    burst = _parse_burst(args.burst, args.slots)
    if burst:
        n_req = max(n_req, burst)
    rng = np.random.default_rng(args.seed)
    imgs_u8 = rng.integers(0, 256, (n_req, yolo.INPUT_SIZE, yolo.INPUT_SIZE,
                                    3), np.uint8)
    params, art = yolo.build_detector(
        jax.random.PRNGKey(args.seed),
        jnp.asarray(imgs_u8[:1], jnp.float32) / 256.0,
        profile=args.profile)

    def stream():
        return [ServeRequest(rid=i, image=imgs_u8[i]) for i in range(n_req)]

    def serve(backend):
        sched = Scheduler(backend, max_queue=max(n_req, 1))
        results = sched.run(stream())
        return results, sched.metrics.summary()

    # one compiled executable per wire, shared across every depth via
    # spawn(depth=K) — the sweep measures the window, not recompiles
    raw_t = DetectionBackend(art, slots=args.slots, depth=1,
                             profile=args.profile)
    raw_t.warmup()                        # compile outside the timed ticks
    dn_t = DetectionBackend(art, slots=args.slots, depth=1,
                            profile=args.profile, device_nms=True)
    dn_t.warmup()

    ss_results, ss_summary = serve(raw_t.spawn(depth=1))
    ov_results, ov_summary = serve(raw_t.spawn(depth=2))

    # K-deep saturation sweep on the headline device-NMS wire: results must
    # stay bit-exact vs single-shot and surface in dispatch order at any K
    depths = sorted({1, 2, 4, 8, args.depth})
    sweep_results, sweep_summaries, depth_sweep = {}, {}, {}
    for k in depths:
        res, summ = serve(dn_t.spawn(depth=k))
        assert [r.rid for r in res] == list(range(n_req)), \
            f"depth={k}: completions left dispatch order"
        sweep_results[k], sweep_summaries[k] = res, summ
        depth_sweep[str(k)] = {
            key: summ[key] for key in
            ("img_per_s", "tick_p50_ms", "tick_p95_ms", "ticks", "wall_s",
             "host_syncs_per_tick", "batch_occupancy")}
    base = {r.rid: r.detections for r in sweep_results[1]}
    for k in depths[1:]:
        for r in sweep_results[k]:
            for leaf, ref_v in base[r.rid].items():
                assert np.array_equal(np.asarray(r.detections[leaf]),
                                      np.asarray(ref_v)), \
                    f"depth={k} diverged from single-shot: rid {r.rid} " \
                    f"field {leaf!r}"
    # headline = the chosen-K sweep run (gated vs committed img_per_s)
    dn_results, summary = sweep_results[args.depth], sweep_summaries[args.depth]

    # K-deep correctness on the raw wire too: depth-2 serving is bit-exact
    # vs single-shot (same fixed-width executable, same batch composition)
    ss_raw = {r.rid: r.detections["raw"] for r in ss_results}
    for r in ov_results:
        assert np.array_equal(r.detections["raw"], ss_raw[r.rid]), \
            f"depth-2 raw head diverged for rid {r.rid}"

    # device-NMS wire correctness: same NMS ran on device in both modes —
    # the compact fp16/int8 emissions must carry the identical detection set
    host_sets = {r.rid: detection.detections_to_list(
        r.detections["boxes"], r.detections["scores"],
        r.detections["classes"]) for r in ov_results}
    for r in dn_results:
        got = detection.detections_to_list(
            r.detections["boxes"], r.detections["scores"],
            r.detections["classes"])
        ref = list(host_sets[r.rid])
        assert len(got) == len(ref) == r.detections["valid"], r.rid
        for d in got:
            for j, e in enumerate(ref):
                iou = float(detection.iou_cxcywh(
                    jnp.asarray(d["box_cxcywh"]),
                    jnp.asarray(e["box_cxcywh"])))
                if (d["class_id"] == e["class_id"] and iou > 0.9
                        and abs(d["score"] - e["score"]) < 0.01):
                    ref.pop(j)
                    break
            else:
                raise AssertionError(
                    f"device-NMS detection unmatched for rid {r.rid}: {d}")
    reduction = (ov_summary["host_sync_bytes_per_sync"]
                 / max(summary["host_sync_bytes_per_sync"], 1e-9))
    assert reduction >= 10.0, \
        f"device-NMS wire only {reduction:.1f}x smaller (need >= 10x)"

    if burst:
        assert summary["requests_dropped"] == 0, summary
        assert summary["requests_completed"] == n_req, summary
        assert summary["host_syncs_per_tick"] <= 1.0 + 1e-9, \
            f"host syncs/tick {summary['host_syncs_per_tick']} > 1"
        print(f"[burst] {n_req} requests ({args.burst}) drained: 0 dropped, "
              f"{summary['host_syncs_per_tick']:.2f} host syncs/tick, "
              f"queue depth max {summary['queue_depth_max']}")

    # fleet tier (--replicas N / --autoscale): the same stream through a
    # Router of spawned replicas must complete the same request-id set with
    # bit-exact payloads as the single-scheduler headline run above
    fleet_record = None
    if args.replicas > 1 or args.autoscale:
        from repro.serve.fleet import (Autoscaler, AutoscalerConfig,
                                       FleetMetrics, Router)
        template = dn_t.spawn(depth=args.depth)   # shares the warm executable
        scaler = None
        if args.autoscale:
            scaler = Autoscaler(AutoscalerConfig(
                min_replicas=args.replicas, max_replicas=2 * args.replicas))
        router = Router(template.spawn, replicas=args.replicas,
                        autoscaler=scaler, metrics=FleetMetrics(),
                        keep_results=True)
        fleet_results = router.run([ServeRequest(rid=i, image=imgs_u8[i])
                                    for i in range(n_req)])
        assert router.metrics.lost == 0 and router.metrics.dropped == 0
        dn_payloads = {r.rid: r.detections for r in dn_results}
        assert sorted(r.rid for r in fleet_results) == sorted(dn_payloads)
        for r in fleet_results:
            ref_p = dn_payloads[r.rid]
            for leaf in ref_p:
                assert np.array_equal(np.asarray(r.detections[leaf]),
                                      np.asarray(ref_p[leaf])), \
                    f"fleet payload diverged: rid {r.rid} field {leaf!r}"
        fleet_record = {"replicas": args.replicas,
                        "autoscale": bool(args.autoscale),
                        "equivalence": "completed-id sets equal, payloads "
                                       "bit-exact vs single-scheduler run",
                        **router.metrics.summary()}
        print(f"[fleet] {n_req} requests through {args.replicas} replicas"
              f"{' (+autoscale)' if args.autoscale else ''}: payloads "
              f"bit-exact vs single-scheduler run")

    # §6.3 alignment of the served (packed/Pallas) path vs float reference
    ref = np.asarray(yolo.yolo_forward_float(
        params, jnp.asarray(imgs_u8, jnp.float32) / 256.0), np.float64)
    served_raw = np.stack([r.detections["raw"] for r in
                           sorted(ov_results, key=lambda r: r.rid)])
    rep = verify.compare("serve_detect_raw", served_raw, ref, lsb=0.02)
    print(rep.row())
    n_boxes = [len(detection.detections_to_list(
        r.detections["boxes"], r.detections["scores"],
        r.detections["classes"])) for r in dn_results]
    curve = ", ".join(f"K={k}: {depth_sweep[str(k)]['img_per_s']:.2f}"
                      for k in depths)
    print(f"served {len(dn_results)} images in {summary['wall_s']:.2f}s "
          f"({summary['img_per_s']:.2f} img/s device-NMS depth={args.depth} "
          f"vs {ov_summary['img_per_s']:.2f} raw-wire depth-2 vs "
          f"{ss_summary['img_per_s']:.2f} single-shot, p50 tick "
          f"{summary['tick_p50_ms']:.1f} ms); saturation img/s [{curve}]; "
          f"detections/img {n_boxes}; "
          f"sync wire {summary['host_sync_bytes_per_sync']:.0f} B/dispatch "
          f"vs {ov_summary['host_sync_bytes_per_sync']:.0f} raw "
          f"({reduction:.1f}x smaller)")
    return {"reduced": args.reduced, "slots": args.slots,
            "burst": args.burst or None, "profile": args.profile,
            "pipelining": f"k_deep_window(depth={args.depth})",
            "depth": args.depth,
            "depth_sweep": depth_sweep,
            "nms": "device",
            "emission_wire": "fp16 boxes+scores, int8 classes, int32 valid",
            "sync_bytes_reduction_vs_raw_wire": reduction,
            "alignment": {"max_abs": rep.max_abs, "mean_abs": rep.mean_abs,
                          "within_1lsb": rep.within_1lsb},
            **({"fleet": fleet_record} if fleet_record else {}),
            **summary,
            "baseline_raw_wire": {"pipelining": "k_deep_window(depth=2)",
                                  "nms": "device_plus_raw_head_wire",
                                  **ov_summary},
            "baseline_single_shot": {"pipelining": "single_shot",
                                     "nms": "device_plus_raw_head_wire",
                                     **ss_summary}}


def run_multires(args) -> dict:
    """≥ 2 resolution buckets through ONE scheduler: per-bucket batches,
    per-bucket executables sharing packed weights, per-bucket references."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import yolo
    from repro.serve import DetectionBackend, Scheduler, ServeRequest

    buckets = tuple(int(b) for b in args.buckets.split(","))
    assert len(buckets) >= 2, "--workload multires needs >= 2 --buckets"
    n_req = max(2 * len(buckets), 4) if args.reduced else args.requests
    n_req = max(n_req, len(buckets))
    rng = np.random.default_rng(args.seed)
    # round-robin bucket assignment: mixed-size traffic through one queue
    sizes = [buckets[i % len(buckets)] for i in range(n_req)]
    imgs = [rng.integers(0, 256, (s, s, 3), np.uint8) for s in sizes]
    _, art = yolo.build_detector(
        jax.random.PRNGKey(args.seed),
        jnp.asarray(imgs[0][None], jnp.float32) / 256.0,
        profile=args.profile, buckets=buckets)

    def stream(rids):
        return [ServeRequest(rid=i, image=imgs[i]) for i in rids]

    def serve(backend, rids):
        sched = Scheduler(backend, max_queue=n_req)
        results = sched.run(stream(rids))
        return results, sched.metrics.summary()

    raw_t = DetectionBackend(art, slots=args.slots, depth=args.depth,
                             profile=args.profile)
    raw_t.warmup()                       # compiles every bucket's executable
    mixed_results, mixed_raw_summary = serve(raw_t.spawn(), range(n_req))
    assert len(mixed_results) == n_req
    mixed_raw = {r.rid: r.detections["raw"] for r in mixed_results}
    for r in mixed_results:              # grid follows the request's bucket
        g = sizes[r.rid] // 32
        assert r.detections["raw"].shape == (g, g, 75), \
            (r.rid, r.detections["raw"].shape)

    # per-bucket reference: the same bucket sub-stream served alone (same
    # executable, same batch composition) must reproduce the mixed run's
    # raw heads bit-exactly
    for b in buckets:
        rids = [i for i in range(n_req) if sizes[i] == b]
        ref_results, _ = serve(raw_t.spawn(depth=1), rids)
        for r in ref_results:
            assert np.array_equal(r.detections["raw"], mixed_raw[r.rid]), \
                f"bucket {b}: mixed raw head diverged for rid {r.rid}"
    print(f"[multires] {n_req} mixed requests across buckets {buckets} "
          f"served through one scheduler; per-bucket raw heads bit-exact "
          f"vs single-resolution reference runs")

    # headline + saturation: device-NMS wire, per-bucket × per-K img/s
    dn_t = DetectionBackend(art, slots=args.slots, depth=args.depth,
                            profile=args.profile, device_nms=True)
    dn_t.warmup()
    dn_results, summary = serve(dn_t.spawn(), range(n_req))
    assert summary["requests_dropped"] == 0, summary
    assert sorted(r.rid for r in dn_results) == list(range(n_req))
    depths = (1, 2) if args.reduced else (1, 2, 4, 8)
    saturation = {}
    for b in buckets:
        rids = [i for i in range(n_req) if sizes[i] == b]
        saturation[str(b)] = {}
        for k in depths:
            _, summ = serve(dn_t.spawn(depth=k), rids)
            saturation[str(b)][str(k)] = {
                "img_per_s": summ["img_per_s"],
                "tick_p50_ms": summ["tick_p50_ms"],
                "tick_p95_ms": summ["tick_p95_ms"],
                "ticks": summ["ticks"]}
        curve = ", ".join(
            f"K={k}: {saturation[str(b)][str(k)]['img_per_s']:.2f}"
            for k in depths)
        print(f"[multires] bucket {b} saturation img/s [{curve}]")
    per_bucket = {str(b): sizes.count(b) for b in buckets}
    print(f"[multires] mixed headline {summary['img_per_s']:.2f} img/s at "
          f"depth={args.depth} ({per_bucket} images/bucket)")
    return {"reduced": args.reduced, "slots": args.slots,
            "profile": args.profile, "depth": args.depth,
            "buckets": list(buckets), "requests_per_bucket": per_bucket,
            "pipelining": f"k_deep_window(depth={args.depth})",
            "nms": "device",
            "reference": "per-bucket raw heads bit-exact vs "
                         "single-resolution runs",
            "saturation": saturation,
            **summary,
            "baseline_raw_wire": mixed_raw_summary}


def run_compose(args) -> dict:
    """Detect→LM composition on one tick loop, zero lost/duplicated."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.models.transformer import init_lm_params
    from repro.serve import (ComposePipeline, ComposeRequest,
                             DetectionBackend, LMBackend, SamplingParams,
                             detections_to_prompt)
    from repro.models import yolo

    n_req = 3 if args.reduced else args.requests
    rng = np.random.default_rng(args.seed)
    bucket = int(args.buckets.split(",")[0])
    imgs = rng.integers(0, 256, (n_req, bucket, bucket, 3), np.uint8)
    _, art = yolo.build_detector(
        jax.random.PRNGKey(args.seed),
        jnp.asarray(imgs[:1], jnp.float32) / 256.0,
        profile=args.profile, buckets=(bucket,))
    detect = DetectionBackend(art, slots=args.slots, depth=args.depth,
                              profile=args.profile, device_nms=True)
    detect.warmup()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    lm_params = init_lm_params(jax.random.PRNGKey(args.seed + 1), cfg)
    lm = LMBackend(cfg, lm_params, slots=args.slots, max_len=args.max_len,
                   seed=args.seed)

    sp = SamplingParams(max_new=args.max_new, temperature=args.temperature,
                       stop_tokens=tuple(args.stop_token))
    pipe = ComposePipeline(detect, lm, vocab=cfg.vocab_size)
    results = pipe.run([ComposeRequest(rid=i, image=imgs[i], sampling=sp)
                        for i in range(n_req)])
    summary = pipe.summary()
    # conservation: every request surfaces exactly once, fully described
    assert summary["lost"] == 0 and summary["duplicated"] == 0, summary
    assert len(results) == n_req
    for r in results:
        assert r.finish_reason in ("length", "stop"), (r.rid, r.finish_reason)
        assert r.detections is not None and len(r.tokens) >= 1
        # hand-off determinism: the prompt IS the detections template
        assert r.prompt == detections_to_prompt(r.detections,
                                                vocab=cfg.vocab_size), r.rid
    assert len(pipe.handoffs) == n_req
    assert all(h.kind == "compose" for h in pipe.handoffs)
    print(f"[compose] {n_req} detect→LM requests completed on one tick "
          f"loop in {summary['ticks']} ticks: 0 lost, 0 duplicated; "
          f"prompts {[list(r.prompt) for r in results[:3]]}...")
    return {"reduced": args.reduced, "slots": args.slots,
            "arch": args.arch, "bucket": bucket, "depth": args.depth,
            "max_new": args.max_new,
            "prompt_template": "describe-token, count-token, class tokens",
            **{k: summary[k] for k in ("submitted", "completed", "lost",
                                       "duplicated", "handoffs", "ticks")},
            "detect": summary["detect"], "lm": summary["lm"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload",
                    choices=("lm", "detect", "multires", "compose"),
                    default="lm")
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--packed", action="store_true",
                    help="deploy 1-bit packed W1A8 weights (lm)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    help="token id ending a request early (repeatable)")
    ap.add_argument("--burst", default="",
                    help="submit the whole stream as one burst, e.g. 4x = "
                         "4×slots requests (detect)")
    ap.add_argument("--depth", type=int, default=2,
                    help="K-deep dispatch window for the headline detect/"
                         "multires/compose runs (the full K sweep is always "
                         "recorded for detect)")
    ap.add_argument("--buckets", default="",
                    help="comma-separated resolution buckets; defaults to "
                         "256,320 for multires and 320 for compose")
    ap.add_argument("--replicas", type=int, default=1,
                    help="detect: also run the stream through a fleet "
                         "Router of N spawned replicas and assert payload "
                         "bit-exactness vs the single-scheduler run")
    ap.add_argument("--autoscale", action="store_true",
                    help="detect: attach an Autoscaler "
                         "(--replicas..2x--replicas) to the fleet run")
    ap.add_argument("--profile", choices=("tuned", "default", "interpret"),
                    default="tuned",
                    help="kernel tuning profile for the detect backend "
                         "(tuned = committed autotune table winners, incl. "
                         "the fused conv+maxpool routing)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--gate-bench", action="store_true",
                    help="fail when host_sync_bytes_per_tick regresses >5%% "
                         "above the committed record (lm/detect) or "
                         "img_per_s at the chosen K drops >5%% below it "
                         "(detect/multires)")
    args = ap.parse_args()
    if not args.buckets:
        args.buckets = "256,320" if args.workload == "multires" else "320"

    committed = {}
    if args.gate_bench:
        p = pathlib.Path(args.out)
        if p.exists():
            try:
                committed = json.loads(p.read_text()).get(
                    args.workload) or {}
            except json.JSONDecodeError:
                committed = {}

    runner = {"lm": run_lm, "detect": run_detect,
              "multires": run_multires, "compose": run_compose}
    record = runner[args.workload](args)

    if args.gate_bench:
        if not committed:
            print(f"[gate] no committed {args.workload} record in "
                  f"{args.out} — gate records, next run enforces")
        else:
            if args.workload in ("lm", "detect") \
                    and committed.get("host_sync_bytes_per_tick") is not None:
                ref = committed["host_sync_bytes_per_tick"]
                got = record["host_sync_bytes_per_tick"]
                assert got <= ref * 1.05, \
                    (f"host_sync_bytes_per_tick regressed: {got:.1f} > "
                     f"committed {ref:.1f} x 1.05")
                print(f"[gate] host_sync_bytes_per_tick {got:.1f} <= "
                      f"committed {ref:.1f} x 1.05 OK")
            if args.workload in ("detect", "multires") \
                    and committed.get("img_per_s") is not None:
                ref = committed["img_per_s"]
                got = record["img_per_s"]
                assert got >= ref * 0.95, \
                    (f"img_per_s at depth={args.depth} regressed: "
                     f"{got:.2f} < committed {ref:.2f} x 0.95")
                print(f"[gate] img_per_s {got:.2f} >= committed "
                      f"{ref:.2f} x 0.95 OK")
            if args.workload == "compose":
                assert record["lost"] == 0 and record["duplicated"] == 0
                print("[gate] compose conservation OK (0 lost, "
                      "0 duplicated)")
    _write_bench(args.out, args.workload, record)


if __name__ == "__main__":
    main()
