"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the 512-device flag before any jax import side effect:
"""
import os  # noqa: E402
import sys  # noqa: E402
if "jax" not in sys.modules:
    # Only force the 512-device pool when jax is still fresh (module
    # execution / dry-run scripts). Library imports from an already-running
    # jax process (tests, notebooks) must not repoison the device count.
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402
import json                # noqa: E402
import re                  # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.shapes import SHAPES, skip_reason       # noqa: E402
from repro.dist import sharding as shard_rules  # noqa: E402
from repro.dist.pipeline import (bubble_fraction,           # noqa: E402
                                 bubble_fraction_1f1b)
from repro.launch.mesh import HW, make_production_mesh     # noqa: E402
from repro.models.transformer import ShardCtx, init_lm_params, lm_forward  # noqa: E402
from repro.optim import adafactor, adamw       # noqa: E402
from repro.serve import engine as serve_engine  # noqa: E402
from repro.serve.packed import deploy_lm       # noqa: E402
from repro.train.step import make_train_step   # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")

# archs whose optimizer state must be factored (≥398B params)
BIG = {"kimi-k2-1t-a32b", "jamba-1.5-large-398b", "internvl2-76b"}


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str) -> dict:
    """Shardable, weak-type-correct stand-ins (no device allocation)."""
    cfg = configs.get_config(arch)
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    f32 = jnp.float32
    out = {}
    if spec.kind in ("train", "prefill"):
        toks = s - (cfg.prefix_len if cfg.frontend == "vision" else 0)
        out["tokens"] = jax.ShapeDtypeStruct((b, toks), jnp.int32)
        if spec.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, toks), jnp.int32)
        if cfg.family == "encdec":
            out["encoder_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                         f32)
        if cfg.frontend == "vision":
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), f32)
    else:                                   # decode: one new token + cache
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return out


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _batch_shardings(specs: dict, mesh, dp) -> dict:
    out = {}
    for k, v in specs.items():
        axes = dp if (dp and v.shape[0] % _axsize(mesh, dp) == 0) else ()
        out[k] = NamedSharding(mesh, P(axes if axes else None,
                                       *([None] * (v.ndim - 1))))
    return out


def _axsize(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _cache_shardings(cache_shapes, mesh, cfg, *, dp, long_ctx: bool,
                     seq_shard_fallback: bool = False):
    """KV/SSM cache sharding: batch over dp when divisible; for long-context
    (batch 1) the KV sequence dim shards over 'data' (SP).

    seq_shard_fallback (§Perf): archs whose kv_heads don't divide |model|
    (granite kv=1, chatglm kv=2, qwen/mixtral/jamba kv=8) replicate the KV
    cache across the model axis by default — the fallback shards the cache
    *sequence* over 'model' instead (XLA partitions the masked softmax with
    a max/sum reduce pair), cutting decode cache memory 16×.
    """
    model = "model"

    def spec_for(path, leaf):
        shp = leaf.shape
        name = jax.tree_util.keystr(path)
        if "lengths" in name:
            return P()
        batch_ok = dp and shp[1] % _axsize(mesh, dp) == 0
        bspec = dp if batch_ok else None
        if "'k'" in name or "'v'" in name:                # (st,B,L,KV,hd)
            seq = "data" if (long_ctx and shp[2] % mesh.shape["data"] == 0
                             and not batch_ok) else None
            kvs = model if shp[3] % mesh.shape[model] == 0 else None
            if kvs is None and seq is None and seq_shard_fallback and \
                    shp[2] % mesh.shape[model] == 0:
                seq = model
            return P(None, bspec, seq, kvs, None)
        if "'pos'" in name:                               # (st,B,L)
            seq = "data" if (long_ctx and shp[2] % mesh.shape["data"] == 0
                             and not batch_ok) else None
            kvs_possible = cfg.num_kv_heads % mesh.shape[model] == 0
            if not kvs_possible and seq is None and seq_shard_fallback and \
                    shp[2] % mesh.shape[model] == 0:
                seq = model
            return P(None, bspec, seq)
        if "conv" in name:                                # (st,B,W-1,C)
            c = model if shp[-1] % mesh.shape[model] == 0 else None
            return P(None, bspec, None, c)
        if "ssm" in name:                                 # (st,B,H,P,N)|(st,B,C,N)
            c = model if shp[2] % mesh.shape[model] == 0 else None
            return P(*([None, bspec, c] + [None] * (len(shp) - 3)))
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, spec_for(p, l)) for p, l in flat])


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def build_train_cell(arch: str, shape_name: str, mesh, *,
                     microbatches: int = 8, mode: str = "w1a8_train"):
    cfg = configs.get_config(arch)
    dp = shard_rules.dp_axes(mesh)
    dtype = jnp.bfloat16 if arch in BIG else jnp.float32
    params_sds = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg, dtype))
    opt = adafactor(1e-3) if arch in BIG else adamw(1e-3)
    opt_sds = jax.eval_shape(opt[0], params_sds)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp, tp_axis="model",
                   ep_axis="data" if cfg.num_experts else None)
    step = make_train_step(cfg, opt, mode=mode, microbatches=microbatches,
                           ctx=ctx, remat=True)
    batch_specs = input_specs(arch, shape_name)
    p_sh = shard_rules.tree_shardings(params_sds, cfg, mesh)
    o_sh = shard_rules.tree_shardings(opt_sds, cfg, mesh)
    b_sh = _batch_shardings(batch_specs, mesh, dp)
    jitted = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    return jitted, (params_sds, opt_sds, batch_specs)


def build_prefill_cell(arch: str, shape_name: str, mesh, *,
                       mode: str = "w1a8_eval", packed: bool = True):
    cfg = configs.get_config(arch)
    dp = shard_rules.dp_axes(mesh)
    params_sds = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    if packed and cfg.w1a8_body:
        params_sds = jax.eval_shape(deploy_lm, params_sds)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp, tp_axis="model",
                   ep_axis="data" if cfg.num_experts else None)
    batch_specs = input_specs(arch, shape_name)

    def fwd(params, batch):
        kw = {k: v for k, v in batch.items() if k != "tokens"}
        return lm_forward(cfg, params, batch["tokens"], mode=mode, ctx=ctx,
                          remat=True, **kw)

    p_sh = shard_rules.tree_shardings(params_sds, cfg, mesh)
    b_sh = _batch_shardings(batch_specs, mesh, dp)
    jitted = jax.jit(fwd, in_shardings=(p_sh, b_sh))
    return jitted, (params_sds, batch_specs)


def build_decode_cell(arch: str, shape_name: str, mesh, *,
                      mode: str = "w1a8_eval", packed: bool = True):
    cfg = configs.get_config(arch)
    spec = SHAPES[shape_name]
    dp = shard_rules.dp_axes(mesh)
    long_ctx = spec.global_batch < _axsize(mesh, dp)
    params_sds = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    if packed and cfg.w1a8_body:
        params_sds = jax.eval_shape(deploy_lm, params_sds)
    cache_sds = jax.eval_shape(
        lambda: serve_engine.init_cache(cfg, spec.global_batch, spec.seq_len,
                                        jnp.bfloat16))
    # MoE: batch-replicated EP still works (DESIGN §6); dp only if divisible
    ctx = ShardCtx(mesh=mesh,
                   dp_axes=dp if not long_ctx else (),
                   tp_axis="model",
                   ep_axis="data" if cfg.num_experts else None)
    tok_specs = input_specs(arch, shape_name)

    def step(params, cache, batch):
        return serve_engine.decode_step(cfg, params, cache, batch["tokens"],
                                        mode=mode, ctx=ctx)

    p_sh = shard_rules.tree_shardings(params_sds, cfg, mesh)
    c_sh = _cache_shardings(cache_sds, mesh, cfg, dp=dp, long_ctx=long_ctx)
    b_sh = _batch_shardings(tok_specs, mesh, dp if not long_ctx else ())
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    return jitted, (params_sds, cache_sds, tok_specs)


def build_cell(arch: str, shape_name: str, mesh, **kw):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train_cell(arch, shape_name, mesh, **kw)
    if kind == "prefill":
        return build_prefill_cell(arch, shape_name, mesh, **kw)
    return build_decode_cell(arch, shape_name, mesh, **kw)


# ---------------------------------------------------------------------------
# Collective parsing + roofline terms (§Roofline)
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*(?:\()?\s*((?:s|f|u|bf|pred|c)[\w\[\],{}\s]*)"
    r"\s*(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|s64|u64|f64)"
                       r"\[([\d,]*)\]")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(\S+)\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)\(", line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES.get(dt, 4)
        out[kind] += nbytes
        counts[kind] += 1
    out["counts"] = counts
    return out


def wire_bytes(coll: dict, n_chips: int) -> float:
    """Effective per-chip ICI traffic (ring formulas).

    all-reduce ≈ 2·size·(n−1)/n; ag/rs ≈ size·(n−1)/n (size = full tensor);
    a2a ≈ size·(n−1)/n; permute = size. HLO shapes are per-device, so
    all-gather outputs are already global-sized; for all-reduce the shape is
    the (replicated) full tensor.
    """
    f = (n_chips - 1) / max(n_chips, 1)
    return (2 * coll["all-reduce"] * f + coll["all-gather"] * f +
            coll["reduce-scatter"] * f + coll["all-to-all"] * f +
            coll["collective-permute"])


def roofline_terms(flops: float, bytes_acc: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """Three §Roofline terms, in seconds (totals are whole-program)."""
    t_comp = flops / (n_chips * HW["peak_flops_bf16"])
    t_mem = bytes_acc / (n_chips * HW["hbm_bw"])
    t_coll = coll_bytes / HW["ici_bw"]        # coll_bytes is per-chip wire
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "bottleneck": dom[0]}


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
    + attention score/value FLOPs (standard MFU accounting; causal ⇒ S²/2,
    SWA ⇒ window-bounded, SSM mixers ⇒ no quadratic term)."""
    cfg = configs.get_config(arch)
    params = jax.eval_shape(
        lambda: init_lm_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        if "_packed" in name:
            n *= 32                              # 1-bit storage, real MACs
        total += n
        if "['moe']" in name and re.search(
                r"\['(up|gate|down)(_packed)?'\]", name):
            active += n * cfg.top_k // max(cfg.num_experts, 1)
        else:
            active += n
    spec = SHAPES[shape_name]
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    mult = 6 if spec.kind == "train" else 2
    flops = mult * active * tokens

    # attention term: 4·H·hd FLOPs per (query, key) pair (QKᵀ + PV)
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.mixer_kind(i).startswith("attn"))
    n_local = sum(1 for i in range(cfg.num_layers)
                  if cfg.mixer_kind(i) == "attn_local" or
                  (cfg.sliding_window and not cfg.local_global and
                   cfg.mixer_kind(i) == "attn"))
    s = spec.seq_len
    per_pair = 4 * cfg.num_heads * cfg.hd
    if spec.kind == "decode":
        ctx_w = min(s, cfg.sliding_window or s)
        flops += spec.global_batch * per_pair * (
            (n_attn - n_local) * s + n_local * ctx_w)
    else:
        pairs_full = s * s / 2
        pairs_win = min(s * s / 2, s * (cfg.sliding_window or s))
        attn = spec.global_batch * per_pair * (
            (n_attn - n_local) * pairs_full + n_local * pairs_win)
        flops += attn * (3 if spec.kind == "train" else 1)
    return flops


# ---------------------------------------------------------------------------
# Pipeline bubble accounting (dist/pipeline helpers)
# ---------------------------------------------------------------------------

def pipeline_bubble_record(cfg, *, microbatches: int = 8) -> dict:
    """Schedule idle fractions if this arch's stage stack were pipelined:
    n = the natural stage partition (num_layers / period), M = the train
    cell's microbatch count. Reported in every train cell so launch tooling
    can size num_micro; the schedules themselves live in dist/pipeline."""
    n = cfg.num_layers // cfg.period
    return {"stages": n, "num_micro": microbatches,
            "gpipe_bubble": round(bubble_fraction(n, microbatches), 4),
            "1f1b_bubble": round(bubble_fraction_1f1b(n, microbatches), 4)}


def bubble_table(stages=(4,), micro=(4, 8, 16)) -> list:
    """gpipe-vs-1f1b idle fractions over (n, M) — the CI-produced source
    for the BENCH_* bench trajectory (see EXPERIMENTS.md §Pipeline)."""
    rows = []
    for n in stages:
        for m in micro:
            rows.append({"stages": n, "num_micro": m,
                         "gpipe_bubble": round(bubble_fraction(n, m), 4),
                         "1f1b_bubble": round(bubble_fraction_1f1b(n, m), 4)})
    return rows


def write_bubble_table(out_path: str = None) -> str:
    out_path = out_path or os.path.join(RESULTS_DIR,
                                        "BENCH_bubble_fraction.json")
    rows = bubble_table()
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print("| n | M | gpipe | 1f1b |")
    print("|---|---|-------|------|")
    for r in rows:
        print(f"| {r['stages']} | {r['num_micro']} | {r['gpipe_bubble']:.3f}"
              f" | {r['1f1b_bubble']:.3f} |")
    return out_path


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save_hlo: bool = False, **kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
           "chips": n_chips}
    skip = skip_reason(arch, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    if SHAPES[shape_name].kind == "train":
        rec["pipeline_bubble"] = pipeline_bubble_record(
            configs.get_config(arch))
    t0 = time.time()
    with mesh:
        jitted, arg_sds = build_cell(arch, shape_name, mesh, **kw)
        lowered = jitted.lower(*arg_sds)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")}
        from repro.compat import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rec["collectives"] = coll
        cw = wire_bytes(coll, n_chips)
        rec["collective_wire_bytes_per_chip"] = cw
        # CPU cost analysis reports whole-program totals; per-chip = /chips
        rec["roofline"] = roofline_terms(flops, bytes_acc, cw, n_chips)
        mf = model_flops(arch, shape_name)
        rec["model_flops"] = mf
        rec["useful_flops_ratio"] = mf / flops if flops else None
        if save_hlo:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            fn = os.path.join(RESULTS_DIR,
                              f"hlo_{arch}_{shape_name}_{rec['mesh']}.txt")
            with open(fn, "w") as f:
                f.write(hlo)
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--bubble-table", action="store_true",
                    help="write benchmarks/results/BENCH_bubble_fraction"
                         ".json (gpipe vs 1f1b idle fractions) and exit")
    args = ap.parse_args()

    if args.bubble_table:
        path = write_bubble_table(args.out)
        print(f"wrote {path}")
        return

    archs = list(configs.ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS_DIR, "dryrun.json")
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}
    results = [r for r in results if r.get("status") in ("ok", "skipped")]

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_name) in done:
                    continue
                print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   save_hlo=args.save_hlo)
                except Exception as e:                     # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
                stat = rec.get("status")
                extra = ""
                if stat == "ok":
                    r = rec["roofline"]
                    extra = (f" comp={r['t_compute_s']:.3g}s "
                             f"mem={r['t_memory_s']:.3g}s "
                             f"coll={r['t_collective_s']:.3g}s "
                             f"→ {r['bottleneck']}")
                elif stat == "error":
                    extra = " " + rec["error"][:200]
                print(f"    {stat}{extra}", flush=True)


if __name__ == "__main__":
    main()
