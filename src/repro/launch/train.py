"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real pod this process runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); in this container it runs the same
code single-host. Supports --reduced for CPU-scale runs, checkpoint/resume,
preemption handling, and the W1A8 QAT mode (the paper's training recipe).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="w1a8_train",
                    choices=["w1a8_train", "float"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgdm"])
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config (CPU-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) pod mesh (needs 256 devices)")
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b"],
                    help="pipelined training schedule (dist/pipeline)")
    ap.add_argument("--pipeline-stages", type=int, default=4,
                    help="pipeline depth n; mesh = (devices/n, n) over "
                         "('data', 'stage')")
    ap.add_argument("--grad-wire", default="fp32",
                    choices=["fp32", "int8"],
                    help="DP gradient all-reduce wire format "
                         "(int8 → dist/collectives.tree_quantized_allreduce)")
    args = ap.parse_args()

    if args.pipeline != "none" and args.production_mesh:
        raise SystemExit("--pipeline and --production-mesh are separate "
                         "mesh layouts; pick one")
    if args.production_mesh and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                                   "256 " + os.environ.get("XLA_FLAGS", ""))
    if args.pipeline != "none" and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # (data, stage) mesh on the 16-device host pool (CPU smoke runs)
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                                   "16 " + os.environ.get("XLA_FLAGS", ""))
    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()       # multi-host pod entry

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.data import pipeline as data
    from repro.dist import sharding as shard_rules
    from repro.models.transformer import ShardCtx, init_lm_params
    from repro.optim import adafactor, adamw, sgdm
    from repro.optim.schedules import cosine_schedule
    from repro.train.loop import resume_or_init, run_train
    from repro.train.step import make_pipeline_train_step, make_train_step

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    sched = cosine_schedule(args.lr, max(args.steps // 20, 1), args.steps)
    opt = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[
        args.optimizer](sched)

    ctx = None
    mesh = None
    b_sh = None
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                       ep_axis="data" if cfg.num_experts else None)

    if args.pipeline != "none":
        # (data, stage) mesh: stage partitioning of the body, DP over data,
        # grads over the fp32/int8 wire (DESIGN.md §9)
        n_dev = len(jax.devices())
        n_st = args.pipeline_stages
        if n_dev % n_st:
            raise SystemExit(f"{n_dev} devices do not split into "
                             f"{n_st} pipeline stages")
        mesh = jax.make_mesh((n_dev // n_st, n_st), ("data", "stage"))
        num_micro = max(args.microbatches, 1)
        raw_step = make_pipeline_train_step(
            cfg, opt, mesh=mesh, num_micro=num_micro, mode=args.mode,
            schedule=args.pipeline, grad_wire=args.grad_wire)
        p_sds = jax.eval_shape(
            lambda: init_lm_params(jax.random.PRNGKey(args.seed), cfg))
        p_sh = shard_rules.pipeline_tree_shardings(p_sds, mesh,
                                                   cfg.num_layers)
        o_sh = shard_rules.pipeline_tree_shardings(
            jax.eval_shape(opt[0], p_sds), mesh, cfg.num_layers)
        b_sh = {"tokens": NamedSharding(mesh, P("data", None)),
                "labels": NamedSharding(mesh, P("data", None))}
        from repro.dist.pipeline import (bubble_fraction,
                                         bubble_fraction_1f1b)
        bf = (bubble_fraction_1f1b if args.pipeline == "1f1b"
              else bubble_fraction)(n_st, num_micro)
        print(f"[pipeline] {args.pipeline} n={n_st} M={num_micro} "
              f"bubble={bf:.3f} grad-wire={args.grad_wire}")
        step_fn = jax.jit(raw_step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    elif mesh is not None:
        # dist-layer wiring: place params/opt state with the sharding rules
        # so jit never has to guess (and resharding collectives never appear)
        raw_step = make_train_step(cfg, opt, mode=args.mode,
                                   microbatches=args.microbatches, ctx=ctx,
                                   remat=not args.reduced)
        p_sds = jax.eval_shape(
            lambda: init_lm_params(jax.random.PRNGKey(args.seed), cfg))
        p_sh = shard_rules.tree_shardings(p_sds, cfg, mesh)
        o_sh = shard_rules.tree_shardings(jax.eval_shape(opt[0], p_sds),
                                          cfg, mesh)
        step_fn = jax.jit(raw_step, in_shardings=(p_sh, o_sh, None),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    else:
        raw_step = make_train_step(cfg, opt, mode=args.mode,
                                   microbatches=args.microbatches, ctx=ctx,
                                   remat=not args.reduced)
        step_fn = jax.jit(raw_step)

    def init_fn():
        params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
        return {"params": params, "opt_state": opt[0](params)}

    shardings = {"params": p_sh, "opt_state": o_sh} if mesh is not None \
        else None
    state, start = resume_or_init(args.ckpt_dir, init_fn,
                                  shardings=shardings)
    ds = data.make_lm_dataset(cfg.vocab_size, args.seq_len,
                              args.global_batch, seed=args.seed)

    def batch_fn(step):
        toks, labels = data.lm_batch(ds, step)
        batch = {"tokens": toks, "labels": labels}
        if cfg.family == "encdec":
            batch["encoder_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, args.seq_len,
                                           cfg.d_model)) * 0.1
        if cfg.frontend == "vision":
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, cfg.prefix_len,
                                           cfg.d_model)) * 0.1
        return batch

    run_train(train_step=step_fn, params=state["params"],
              opt_state=state["opt_state"], batch_fn=batch_fn,
              steps=args.steps, start_step=start, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
