"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On a real pod this process runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); in this container it runs the same
code single-host. Supports --reduced for CPU-scale runs, checkpoint/resume,
preemption handling, and the W1A8 QAT mode (the paper's training recipe).
"""
from __future__ import annotations

import argparse
import functools
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="w1a8_train",
                    choices=["w1a8_train", "float"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgdm"])
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config (CPU-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) pod mesh (needs 256 devices)")
    args = ap.parse_args()

    if args.production_mesh and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                                   "256 " + os.environ.get("XLA_FLAGS", ""))
    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()       # multi-host pod entry

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.data import pipeline as data
    from repro.models.transformer import ShardCtx, init_lm_params
    from repro.optim import adafactor, adamw, sgdm
    from repro.optim.schedules import cosine_schedule
    from repro.train.loop import resume_or_init, run_train
    from repro.train.step import make_train_step

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    sched = cosine_schedule(args.lr, max(args.steps // 20, 1), args.steps)
    opt = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[
        args.optimizer](sched)

    ctx = None
    mesh = None
    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                       ep_axis="data" if cfg.num_experts else None)

    raw_step = make_train_step(cfg, opt, mode=args.mode,
                               microbatches=args.microbatches, ctx=ctx,
                               remat=not args.reduced)
    if mesh is not None:
        # dist-layer wiring: place params/opt state with the sharding rules
        # so jit never has to guess (and resharding collectives never appear)
        from repro.dist import sharding as shard_rules
        p_sds = jax.eval_shape(
            lambda: init_lm_params(jax.random.PRNGKey(args.seed), cfg))
        p_sh = shard_rules.tree_shardings(p_sds, cfg, mesh)
        o_sh = shard_rules.tree_shardings(jax.eval_shape(opt[0], p_sds),
                                          cfg, mesh)
        step_fn = jax.jit(raw_step, in_shardings=(p_sh, o_sh, None),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(raw_step)

    def init_fn():
        params = init_lm_params(jax.random.PRNGKey(args.seed), cfg)
        return {"params": params, "opt_state": opt[0](params)}

    shardings = {"params": p_sh, "opt_state": o_sh} if mesh is not None \
        else None
    state, start = resume_or_init(args.ckpt_dir, init_fn,
                                  shardings=shardings)
    ds = data.make_lm_dataset(cfg.vocab_size, args.seq_len,
                              args.global_batch, seed=args.seed)

    def batch_fn(step):
        toks, labels = data.lm_batch(ds, step)
        batch = {"tokens": toks, "labels": labels}
        if cfg.family == "encdec":
            batch["encoder_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, args.seq_len,
                                           cfg.d_model)) * 0.1
        if cfg.frontend == "vision":
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.global_batch, cfg.prefix_len,
                                           cfg.d_model)) * 0.1
        return batch

    run_train(train_step=step_fn, params=state["params"],
              opt_state=state["opt_state"], batch_fn=batch_fn,
              steps=args.steps, start_step=start, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
