"""Production mesh definitions (TPU v5e pods).

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=('data','model') single pod / (2,16,16)=('pod','data','model')
    two pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    if n < data * model:
        data, model = 1, min(n, model)
    return jax.make_mesh((data, model), ("data", "model"))


HW = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_bw": 819e9,                # B/s per chip
    "ici_bw": 50e9,                 # B/s per link (~per-direction)
    "hbm_gib": 16,
}
