"""Training stack: QAT train step (microbatch grad accumulation), loop with
checkpoint/restart + preemption handling, YOLO detection training."""
from repro.train.step import make_train_step  # noqa: F401
from repro.train.loop import run_train  # noqa: F401
