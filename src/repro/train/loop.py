"""Training loop with fault-tolerance plumbing.

Restart contract: checkpoint = (params, opt_state, step[, metadata]); data
is stateless-by-step so resume is exact. Preemption: SIGTERM or a
``<ckpt_dir>/PREEMPT`` sentinel file triggers save-and-exit at the next step
boundary (the SLURM/Borg grace-period pattern). A per-step watchdog logs
straggler steps (wall-clock > watchdog_factor × median).
"""
from __future__ import annotations

import os
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro import ckpt as ckpt_lib


class _PreemptFlag:
    def __init__(self):
        self.hit = False

    def install(self):
        try:
            signal.signal(signal.SIGTERM, lambda *_: setattr(self, "hit", True))
        except ValueError:
            pass                    # non-main thread (tests)


def run_train(*, train_step: Callable, params, opt_state,
              batch_fn: Callable, steps: int,
              ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
              start_step: int = 0, log_every: int = 10,
              async_ckpt: bool = True, watchdog_factor: float = 3.0,
              print_fn: Callable = print):
    """Generic loop; batch_fn(step) → batch dict. Returns final state."""
    flag = _PreemptFlag()
    flag.install()
    durations = []
    step = start_step
    for step in range(start_step, steps):
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            print_fn(f"step {step:5d} loss {loss:.4f} "
                     f"gnorm {float(metrics['grad_norm']):.3f}")
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}")
        dt = time.time() - t0
        durations.append(dt)
        med = float(np.median(durations[-50:]))
        if len(durations) > 5 and dt > watchdog_factor * med:
            print_fn(f"[watchdog] step {step} took {dt:.2f}s "
                     f"(median {med:.2f}s) — straggler suspected")
        preempt = flag.hit or (ckpt_dir and
                               os.path.exists(os.path.join(ckpt_dir,
                                                           "PREEMPT")))
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or preempt or
                         step == steps - 1):
            ckpt_lib.save_checkpoint(
                ckpt_dir, step + 1,
                {"params": params, "opt_state": opt_state},
                metadata={"loss": float(metrics["loss"])},
                async_=async_ckpt and not preempt)
        if preempt:
            print_fn(f"[preempt] checkpointed at step {step + 1}; exiting")
            break
    ckpt_lib.wait_for_async()
    return params, opt_state, step + 1


def resume_or_init(ckpt_dir: Optional[str], init_fn: Callable,
                   shardings=None, print_fn: Callable = print):
    """Elastic restore: loads the latest checkpoint onto the *current* mesh
    (shardings), regardless of the mesh it was saved from."""
    template = jax.eval_shape(init_fn)
    if ckpt_dir:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            state, meta = ckpt_lib.restore_checkpoint(
                ckpt_dir, last, template, shardings=shardings)
            print_fn(f"[resume] restored step {last} from {ckpt_dir}")
            return state, last
    state = init_fn()
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, 0
