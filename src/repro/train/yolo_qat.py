"""QAT training for the paper's W1A8 detector (the paper's training recipe:
latent fp weights + sign-STE forward, LSQ activation steps — §3.2).

Loss is YOLOv3-style on the single 10×10 head: MSE on σ(tx),σ(ty) and raw
tw,th at assigned cells, BCE on objectness and classes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.pipeline import yolo_target
from repro.models import yolo
from repro.models.yolo import GRID, NUM_ANCHORS, NUM_CLASSES
from repro.optim import apply_updates, clip_by_global_norm


def yolo_loss(params, images, target):
    """target: (B,G,G,A,5+C) rasterized ground truth (data.yolo_target)."""
    raw = yolo.yolo_forward_float(params, images, train=True)
    r = raw.reshape(raw.shape[0], GRID, GRID, NUM_ANCHORS, 5 + NUM_CLASSES)
    obj_t = target[..., 4]
    pos = obj_t > 0.5

    pxy = jax.nn.sigmoid(r[..., 0:2])
    # box centers relative to cell
    cell = jnp.stack(jnp.meshgrid(jnp.arange(GRID), jnp.arange(GRID),
                                  indexing="ij"), -1)[None, :, :, None, :]
    txy_t = target[..., 0:2] * GRID - cell[..., ::-1]
    loss_xy = jnp.sum(jnp.where(pos[..., None],
                                (pxy - txy_t) ** 2, 0.0))
    wh_t = jnp.log(jnp.clip(target[..., 2:4], 1e-3, 1.0))
    loss_wh = jnp.sum(jnp.where(pos[..., None],
                                (r[..., 2:4] - wh_t) ** 2, 0.0))
    obj_logit = r[..., 4]
    loss_obj = jnp.mean(
        jnp.maximum(obj_logit, 0) - obj_logit * obj_t +
        jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))
    cls_logit = r[..., 5:]
    cls_t = target[..., 5:]
    bce = (jnp.maximum(cls_logit, 0) - cls_logit * cls_t +
           jnp.log1p(jnp.exp(-jnp.abs(cls_logit))))
    loss_cls = jnp.sum(jnp.where(pos[..., None], bce, 0.0))
    npos = jnp.maximum(jnp.sum(pos), 1.0)
    return (loss_xy + loss_wh + loss_cls) / npos + loss_obj


def make_yolo_train_step(optimizer, *, max_grad_norm: float = 5.0):
    _, update = optimizer

    @jax.jit
    def step_fn(params, opt_state, images, boxes, classes):
        target = yolo_target(boxes, classes)

        loss, grads = jax.value_and_grad(yolo_loss)(params, images, target)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "step": opt_state["step"]}

    return step_fn
