"""Train step builder: QAT loss, microbatch grad-accum scan, clip, update.

Gradient accumulation is a `lax.scan` over microbatches — XLA overlaps each
microbatch's gradient psum (inserted by SPMD for the DP axes) with the next
microbatch's backward pass, the standard comm/compute overlap. Buffers are
donated (params/opt_state) by the caller's jit.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import lm_forward
from repro.optim import apply_updates, clip_by_global_norm

tmap = jax.tree_util.tree_map


def lm_loss(cfg, params, batch, *, mode: str, ctx=None,
            remat: bool = True) -> jax.Array:
    kw = {}
    if "encoder_embeds" in batch:
        kw["encoder_embeds"] = batch["encoder_embeds"]
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    logits = lm_forward(cfg, params, batch["tokens"], mode=mode, ctx=ctx,
                        remat=remat, **kw)
    seq = batch["tokens"].shape[1]
    logits = logits[:, -seq:, :]                       # drop modality prefix
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    # z-loss stabilizes the (vocab-sharded) softmax at scale
    zloss = 1e-4 * jnp.mean(jax.scipy.special.logsumexp(
        logits.astype(jnp.float32), -1) ** 2)
    return jnp.mean(nll) + zloss


def make_train_step(cfg, optimizer, *, mode: str = "w1a8_train",
                    microbatches: int = 1, max_grad_norm: float = 1.0,
                    ctx=None, remat: bool = True,
                    loss_fn: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    batch: dict of arrays with leading dim = per-step global batch; it is
    split into `microbatches` equal slices accumulated in f32.
    """
    _, update = optimizer
    loss_fn = loss_fn or functools.partial(lm_loss, cfg, mode=mode, ctx=ctx,
                                           remat=remat)

    def grads_of(params, mb):
        return jax.value_and_grad(lambda p: loss_fn(p, mb))(params)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = tmap(split, batch)

            def acc_fn(acc, mb):
                loss, grads = grads_of(params, mb)
                acc = (acc[0] + loss,
                       tmap(lambda a, g: a + g.astype(jnp.float32),
                            acc[1], grads))
                return acc, None

            zero = (jnp.zeros((), jnp.float32),
                    tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, gsum), _ = jax.lax.scan(acc_fn, zero, mbs)
            loss = loss_sum / microbatches
            grads = tmap(lambda g: g / microbatches, gsum)
        else:
            loss, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return train_step
