"""Train step builders: QAT loss, microbatch grad-accum scan, clip, update.

Gradient accumulation is a `lax.scan` over microbatches — XLA overlaps each
microbatch's gradient psum (inserted by SPMD for the DP axes) with the next
microbatch's backward pass, the standard comm/compute overlap. Buffers are
donated (params/opt_state) by the caller's jit.

:func:`make_pipeline_train_step` is the pipelined variant (DESIGN.md §9):
body layers partition into ``|stage|`` pipeline stages driven by the
1F1B/GPipe schedules in ``dist/pipeline``, with the DP gradient reduction
running over ``dist/collectives.tree_quantized_allreduce`` when the int8
wire is selected.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.pipeline import (pipeline_train_local,
                                 reduce_pipeline_outputs)
from repro.models.layers import embed, norm, unembed
from repro.models.transformer import _apply_slot, lm_forward
from repro.optim import apply_updates, clip_by_global_norm

tmap = jax.tree_util.tree_map


def lm_loss(cfg, params, batch, *, mode: str, ctx=None,
            remat: bool = True) -> jax.Array:
    kw = {}
    if "encoder_embeds" in batch:
        kw["encoder_embeds"] = batch["encoder_embeds"]
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    logits = lm_forward(cfg, params, batch["tokens"], mode=mode, ctx=ctx,
                        remat=remat, **kw)
    seq = batch["tokens"].shape[1]
    logits = logits[:, -seq:, :]                       # drop modality prefix
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    # z-loss stabilizes the (vocab-sharded) softmax at scale
    zloss = 1e-4 * jnp.mean(jax.scipy.special.logsumexp(
        logits.astype(jnp.float32), -1) ** 2)
    return jnp.mean(nll) + zloss


def make_train_step(cfg, optimizer, *, mode: str = "w1a8_train",
                    microbatches: int = 1, max_grad_norm: float = 1.0,
                    ctx=None, remat: bool = True,
                    loss_fn: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    batch: dict of arrays with leading dim = per-step global batch; it is
    split into `microbatches` equal slices accumulated in f32.
    """
    _, update = optimizer
    loss_fn = loss_fn or functools.partial(lm_loss, cfg, mode=mode, ctx=ctx,
                                           remat=remat)

    def grads_of(params, mb):
        return jax.value_and_grad(lambda p: loss_fn(p, mb))(params)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = tmap(split, batch)

            def acc_fn(acc, mb):
                loss, grads = grads_of(params, mb)
                acc = (acc[0] + loss,
                       tmap(lambda a, g: a + g.astype(jnp.float32),
                            acc[1], grads))
                return acc, None

            zero = (jnp.zeros((), jnp.float32),
                    tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, gsum), _ = jax.lax.scan(acc_fn, zero, mbs)
            loss = loss_sum / microbatches
            grads = tmap(lambda g: g / microbatches, gsum)
        else:
            loss, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return train_step


def make_pipeline_train_step(cfg, optimizer, *, mesh, num_micro: int,
                             mode: str = "w1a8_train",
                             schedule: str = "1f1b",
                             grad_wire: str = "fp32",
                             max_grad_norm: float = 1.0,
                             stage_axis: str = "stage",
                             dp_axis: str = "data"):
    """Pipelined train_step(params, opt_state, batch) → (params, opt, m).

    The body's ``num_layers`` slots partition into ``n = |stage_axis|``
    contiguous stages; microbatches stream through the 1F1B (or GPipe)
    schedule of ``dist.pipeline`` with activations/cotangents hopping
    between neighbouring stages via collective_permute. The embedding
    front-end and the final-norm + LM-head loss run outside the pipeline
    (stage maths must be shape-preserving); the input cotangent returned by
    the pipeline continues the backward into the embedding. Grads reduce
    across ``dp_axis`` — int8-on-the-wire when ``grad_wire == 'int8'``.
    """
    n = int(mesh.shape[stage_axis])
    dp_n = int(mesh.shape[dp_axis])
    if cfg.period != 1:
        raise ValueError("--pipeline needs a uniform layer stack (period 1);"
                         f" {cfg.name} has period {cfg.period}")
    if cfg.encoder_layers or cfg.frontend == "vision":
        raise ValueError(f"--pipeline does not support {cfg.name}'s "
                         "encoder/vision front-end")
    if cfg.ffn_kind(0) == "moe":
        raise ValueError("--pipeline does not support MoE FFNs yet")
    if cfg.num_layers % n:
        raise ValueError(f"{cfg.num_layers} layers do not partition into "
                         f"{n} pipeline stages")
    lps = cfg.num_layers // n
    mk, fk = cfg.mixer_kind(0), cfg.ffn_kind(0)
    _, update = optimizer

    def stage_fn(w, x):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        for i in range(lps):
            slot = tmap(lambda l: l[i], w)
            x = _apply_slot(slot, cfg, x, mixer_kind=mk, ffn_kind=fk,
                            mode=mode, positions=positions, ctx=None)
        return x

    def loss_fn(top, y, aux):
        h = norm(top["final_norm"], y, cfg.norm_kind)
        logits = unembed(top["embed"], cfg, h).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, aux["labels"][..., None],
                                   -1)[..., 0]
        zloss = 1e-4 * jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
        return jnp.mean(nll) + zloss

    local = pipeline_train_local(stage_fn, loss_fn, axis=stage_axis,
                                 num_stages=n, num_micro=num_micro,
                                 schedule=schedule)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        bsz = tokens.shape[0]
        if bsz % dp_n or (bsz // dp_n) % num_micro:
            raise ValueError(f"global batch {bsz} must split into {dp_n} DP"
                             f" shards × {num_micro} microbatches")
        x, f_emb = jax.vjp(lambda e: embed(e, tokens), params["embed"])
        ws = tmap(lambda l: l.reshape((n, lps) + l.shape[1:]),
                  params["slots"][0])
        top = {"embed": params["embed"], "final_norm": params["final_norm"]}

        def prog(ws_l, top_l, x_l, lab_l):
            mbs = x_l.shape[0] // num_micro
            xm = x_l.reshape((num_micro, mbs) + x_l.shape[1:])
            lm = lab_l.reshape((num_micro, mbs) + lab_l.shape[1:])
            out = local(ws_l, top_l, xm, {"labels": lm})
            loss, gw, gtop, dxs = reduce_pipeline_outputs(
                *out, axis=stage_axis, dp_axis=dp_axis, grad_wire=grad_wire)
            return (loss, tmap(lambda g: g[None], gw), gtop,
                    dxs.reshape(x_l.shape))

        w_specs = tmap(lambda l: P(stage_axis, *([None] * (l.ndim - 1))),
                       ws)
        t_specs = tmap(lambda l: P(), top)
        loss, gws, gtop, dx = jax.shard_map(
            prog, mesh=mesh,
            in_specs=(w_specs, t_specs, P(dp_axis, None, None),
                      P(dp_axis, None)),
            out_specs=(P(), w_specs, t_specs, P(dp_axis, None, None)),
            check_vma=False)(ws, top, x, labels)
        (g_emb_front,) = f_emb(dx)
        grads = {"embed": tmap(jnp.add, gtop["embed"], g_emb_front),
                 "final_norm": gtop["final_norm"],
                 "slots": (tmap(lambda g: g.reshape((cfg.num_layers,)
                                                    + g.shape[2:]), gws),)}
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return train_step
