"""JAX API compatibility shims.

The distribution layer (and its tests) target the modern spelling
``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``.
Older jax releases (< 0.5) only ship ``jax.experimental.shard_map.shard_map``
with the ``check_rep`` keyword. Importing this module installs a forwarding
wrapper onto the ``jax`` namespace so both spellings work everywhere.

Import-order safe: this module imports jax itself, so it must only be pulled
in from modules that already import jax at module scope (never from package
``__init__``s that scripts import *before* setting XLA_FLAGS).
"""
from __future__ import annotations

import jax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        # modern name for replication checking; legacy jax calls it check_rep
        if "check_vma" in kwargs and "check_rep" not in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        kwargs.pop("check_vma", None)
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of a concrete 1 folds to the static mapped-axis size
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


def _install_pallas_compiler_params() -> None:
    """Pallas renamed TPUCompilerParams → CompilerParams; alias the old name
    so kernels written against the modern API run on older jax."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:                                # pallas not available
        return
    if not hasattr(pltpu, "CompilerParams") and \
            hasattr(pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def cost_analysis_dict(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions: older jax
    returns a one-element list of dicts, newer jax the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


_install_shard_map()
_install_axis_size()
_install_pallas_compiler_params()
