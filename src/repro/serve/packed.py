"""Deployment packing for LM serving — the parameter-extraction step (§4)
generalized: every W1A8 projection's latent weights become 1-bit sign words.

HBM footprint of the body drops 32× vs f32 / 16× vs bf16:
kimi-k2's 1.04T params → ≈134 GB packed (+ per-channel scales), which is
what makes the 1T-MoE servable on a single 256-chip pod (DESIGN.md §5).
Decode steps are weight-bandwidth-bound, so the memory-roofline term drops
by the same factor — measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing


def _pack_linear(p: dict) -> dict:
    """Pack along the K (second-to-last) axis — stacked per-stage params
    carry leading (n_stages,) / (n_stages, E) dims that must be preserved."""
    w = p["w"]
    kax = w.ndim - 2
    out = {"w_packed": packing.pack_signs(w, axis=kax),
           "alpha": jnp.mean(jnp.abs(w), axis=kax).astype(jnp.float32),
           "act_step": jnp.broadcast_to(
               p["act_step"][..., None] if p["act_step"].ndim else
               p["act_step"], w.shape[:-1]).astype(jnp.float32)}
    if "b" in p:
        out["b"] = p["b"]
    return out


def _pack_moe(p: dict) -> dict:
    out = dict(p)
    for name in ("up", "gate", "down"):
        w = p[name]                                 # (..., E, K, N)
        kax = w.ndim - 2
        out[name + "_packed"] = packing.pack_signs(w, axis=kax)
        out[name + "_alpha"] = jnp.mean(jnp.abs(w), axis=kax,
                                        keepdims=True).astype(jnp.float32)
        del out[name]
    return out


def deploy_lm(params):
    """Walk the param tree, packing every W1A8 projection (dicts holding
    both 'w' and 'act_step'). Non-quantized leaves pass through."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and "act_step" in node:
                return _pack_linear(node)
            if "router" in node and "up" in node:
                return _pack_moe(node) if "act_step" in node else \
                    {k: walk(v) for k, v in node.items()}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(params)


def packed_param_bytes(tree) -> dict:
    """Byte accounting: packed vs bf16-equivalent (the 16× claim, audited)."""
    packed = eq_bf16 = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = jax.tree_util.keystr(path)
        nbytes = int(leaf.size) * leaf.dtype.itemsize
        packed += nbytes
        if "packed" in name:
            eq_bf16 += int(leaf.size) * 32 * 2      # 32 signs/word → bf16
        else:
            eq_bf16 += int(leaf.size) * 2
    return {"packed_bytes": packed, "bf16_equivalent_bytes": eq_bf16,
            "ratio": eq_bf16 / max(packed, 1)}
