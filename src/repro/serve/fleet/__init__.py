"""Fleet-scale serving (DESIGN.md §14): a Router of N backend replicas —
each its own Scheduler slot pool — with least-queue-depth dispatch
(deadline-slack tie-break), a metrics-driven Autoscaler under hysteresis,
and a FleetMetrics roll-up (per-replica + fleet p50/p95, drop-by-cause,
scale events). `launch/traffic.py` replays synthetic diurnal/burst traces
through this tier — millions of requests via the pure-python ModelBackend,
a reduced run via real DetectionBackend replicas."""
from repro.serve.fleet.autoscaler import (Autoscaler,  # noqa: F401
                                          AutoscalerConfig)
from repro.serve.fleet.metrics import FleetMetrics  # noqa: F401
from repro.serve.fleet.model import ModelBackend  # noqa: F401
from repro.serve.fleet.router import Replica, Router  # noqa: F401
