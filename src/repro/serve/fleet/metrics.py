"""FleetMetrics — the roll-up above per-replica EngineMetrics.

One FleetMetrics instance is the `result_sink` of every replica scheduler in
a Router: results stream through it (counts, SLO attainment, end-to-end
latency in ticks) instead of accumulating as live ServeResult objects — the
million-request traffic replay holds O(1) per request. Per-tick fleet state
(replica count, total queued/active) and autoscaler scale events land here
too, so `summary()` yields the whole serving story: fleet p50/p95 latency,
drop-by-cause counts, attainment %, and the replicas-over-time timeline.

Drop causes are split three ways — "rejected" (bounded queue full at
submit), "expired" (admission deadline passed while queued) and
"expired_inflight" (completion deadline overran in a slot). The sink
distinguishes the two expiries structurally: an admission expiry never held
a slot (n_ticks == 0), an in-flight expiry did (n_ticks >= 1).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.api import ServeResult

_COMPLETED = ("ok", "stop", "length")


@dataclasses.dataclass
class FleetMetrics:
    """Fleet-wide accounting. ``slo_ticks`` is the end-to-end (wait +
    service) completion budget a request must meet to count as attained;
    None disables attainment accounting (attainment reports 0.0)."""
    slo_ticks: Optional[int] = None
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    expired: int = 0
    expired_inflight: int = 0
    slo_met: int = 0
    latency_ticks: List[int] = dataclasses.field(default_factory=list)
    # (tick, n_live_replicas) change points — constant fleets have one entry
    replica_timeline: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)
    scale_events: List[dict] = dataclasses.field(default_factory=list)
    queued_peak: int = 0
    ticks: int = 0

    # -- result sink (wired as every replica Scheduler's result_sink) --------
    def on_result(self, res: ServeResult) -> None:
        self.submitted += 1
        if res.finish_reason in _COMPLETED:
            self.completed += 1
            lat = res.wait_ticks + res.n_ticks
            self.latency_ticks.append(lat)
            if self.slo_ticks is not None and lat <= self.slo_ticks:
                self.slo_met += 1
        elif res.finish_reason == "rejected":
            self.rejected += 1
        elif res.n_ticks > 0:          # held a slot: completion-deadline drop
            self.expired_inflight += 1
        else:                          # expired in the wait queue
            self.expired += 1

    # -- fleet state (recorded by Router.tick) -------------------------------
    def record_tick(self, tick: int, n_live: int, queued: int) -> None:
        self.ticks = tick + 1
        self.queued_peak = max(self.queued_peak, queued)
        if (not self.replica_timeline
                or self.replica_timeline[-1][1] != n_live):
            self.replica_timeline.append((tick, n_live))

    def record_scale(self, tick: int, action: str, replica: int,
                     n_live: int) -> None:
        self.scale_events.append({"tick": tick, "action": action,
                                  "replica": replica, "n_live": n_live})

    # -- roll-up -------------------------------------------------------------
    @property
    def dropped(self) -> int:
        return self.rejected + self.expired + self.expired_inflight

    @property
    def lost(self) -> int:
        """Requests submitted but never surfaced as ANY result — the
        conservation gap. Must be 0: completed + every drop cause =
        submitted."""
        return self.submitted - self.completed - self.dropped

    def summary(self) -> dict:
        # all-rejected windows complete nothing: every ratio/quantile falls
        # back to 0.0 — NaN-free by the same contract as EngineMetrics
        lat = (np.asarray(self.latency_ticks) if self.latency_ticks
               else np.zeros(1))
        replicas = [n for _, n in self.replica_timeline] or [0]
        return {
            "ticks": self.ticks,
            "requests_submitted": self.submitted,
            "requests_completed": self.completed,
            "requests_lost": self.lost,
            "drops_by_cause": {"rejected": self.rejected,
                               "expired_admission": self.expired,
                               "expired_inflight": self.expired_inflight},
            "slo_ticks": self.slo_ticks,
            "slo_attainment": (self.slo_met / self.submitted
                               if self.submitted else 0.0),
            "latency_p50_ticks": float(np.quantile(lat, 0.50)),
            "latency_p95_ticks": float(np.quantile(lat, 0.95)),
            "queued_peak": self.queued_peak,
            "replicas_min": min(replicas),
            "replicas_max": max(replicas),
            "replicas_final": replicas[-1],
            "scale_events": self.scale_events,
            "replica_timeline": [[t, n] for t, n in self.replica_timeline],
        }
