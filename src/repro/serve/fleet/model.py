"""ModelBackend — the pure-python replica model for fleet-scale replay.

A jax-free stand-in for DetectionBackend with the same scheduler-visible
contract (capacity / admit_width / admit / step / harvest / release): a
fixed device batch width, every admitted request completing
``service_ticks`` after admission with one final payload emission. With
``depth=K`` it mirrors the K-deep DetectionBackend pool sizing: K×width
slots but width admissions per tick, so batch t computes while the next
batches stage — steady-state throughput is ``depth_factor·width/
service_ticks`` requests per tick. (``overlap=True`` is the retired
spelling of ``depth=2``.) One tick of this backend models one fixed-width detector
dispatch whose wall cost is carried OUT of band (`tick_ms`, calibrated from
the committed BENCH_serve.json detect record) — so a million-request
traffic replay runs at pure-python speed while SLO accounting stays in
scheduler ticks, the unit the real fleet shares.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.serve.api import Emission, ServeRequest


class ModelBackend:
    def __init__(self, width: int = 2, service_ticks: int = 1,
                 tick_ms: float = 0.0, overlap: bool = False,
                 depth: int = None):
        if depth is None:
            depth = 2 if overlap else 1
        self.depth = max(int(depth), 1)
        self.capacity = self.depth * width
        self.admit_width = width
        self.service_ticks = max(int(service_ticks), 1)
        self.tick_ms = float(tick_ms)      # modeled wall cost per tick
        self._rows: Dict[int, int] = {}    # slot -> ticks left
        self._ems: Dict[int, List[Emission]] = {}

    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        for slot, _ in assignments:
            self._rows[slot] = self.service_ticks

    def step(self) -> None:
        for slot in self._rows:
            self._rows[slot] -= 1
            if self._rows[slot] <= 0:
                self._ems.setdefault(slot, []).append(
                    Emission(kind="detections", payload=None, final=True))

    def harvest(self) -> Dict[int, List[Emission]]:
        out, self._ems = self._ems, {}
        return out

    def release(self, slot: int) -> None:
        self._rows.pop(slot, None)
        self._ems.pop(slot, None)
