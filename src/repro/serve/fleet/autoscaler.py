"""Autoscaler — metrics-driven replica count control with hysteresis.

The control loop consumes the EngineMetrics every replica scheduler already
emits (queue_depth, occupancy, p95 tick latency) over a trailing window and
returns a delta: +1 (add a replica), -1 (drain one), 0 (hold). Hysteresis
comes from three mechanisms so the loop cannot flap:

  * separate watermarks — scale up on sustained queue pressure
    (mean queued per live slot > queue_high, or p95 tick latency above
    ``p95_tick_high_ms`` when configured); scale down only when the queue
    is EMPTY across the window and occupancy sits below occ_low;
  * cooldowns — after ANY scale event, no further up-decision for
    ``cooldown_up`` ticks and no down-decision for ``cooldown_down`` ticks
    (down is the slower side: draining is cheap to delay, thrash is not);
  * a full-window warmup — a replica younger than ``window`` ticks
    contributes no samples yet, and decisions wait for a full window.

The autoscaler only *decides*; the Router applies the decision (spawning a
replica, or marking the least-loaded one draining so it finishes its queued
and in-flight work before retiring — scale-down never strands work).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 4
    window: int = 8                 # trailing ticks averaged per signal
    queue_high: float = 2.0         # mean queued per live slot → scale up
    occ_low: float = 0.5            # mean occupancy floor for scale-down
    p95_tick_high_ms: float = 0.0   # optional latency overload signal (0=off)
    cooldown_up: int = 8            # ticks after any event before next up
    cooldown_down: int = 24         # ticks after any event before next down


class Autoscaler:
    def __init__(self, config: AutoscalerConfig = AutoscalerConfig()):
        self.config = config
        self._last_event = -10**9

    def decide(self, tick: int, schedulers: Sequence) -> int:
        """Return +1 / -1 / 0 given the live (non-draining) replicas'
        schedulers. Reads each scheduler's EngineMetrics trailing window."""
        cfg = self.config
        n = len(schedulers)
        if n == 0:
            return +1
        w = cfg.window
        depth = occ = slots = 0.0
        p95 = 0.0
        for sched in schedulers:
            m = sched.metrics
            if len(m.queue_depth) < w:           # young replica: wait
                return 0
            depth += sum(m.queue_depth[-w:]) / w
            occ += sum(m.occupancy[-w:]) / w
            slots += m.capacity
            if cfg.p95_tick_high_ms > 0:         # optional latency signal
                p95 = max(p95, float(np.quantile(m.tick_s[-w:], 0.95)) * 1e3)
        queue_per_slot = depth / max(slots, 1.0)
        overload = queue_per_slot > cfg.queue_high or (
            cfg.p95_tick_high_ms > 0 and p95 > cfg.p95_tick_high_ms)
        if (overload and n < cfg.max_replicas
                and tick - self._last_event >= cfg.cooldown_up):
            self._last_event = tick
            return +1
        idle = depth == 0.0 and (occ / n) < cfg.occ_low
        if (idle and n > cfg.min_replicas
                and tick - self._last_event >= cfg.cooldown_down):
            self._last_event = tick
            return -1
        return 0
