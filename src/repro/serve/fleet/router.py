"""Router — the fleet tier above `serve.Scheduler` (DESIGN.md §14).

A Router fronts N replicas. Each replica wraps its own backend (built by
``backend_factory``) behind its own Scheduler slot pool, so everything the
single-process serving stack guarantees — paged admission, EDF-within-
priority, deadline expiry, slot conservation — holds per replica; the
Router adds dispatch, elasticity and fleet accounting:

  dispatch   submit() routes each request to the live replica with the
             least wait-queue depth; ties break toward the replica whose
             earliest queued admission deadline leaves the MOST slack
             (deadline pressure is load the depth number can't see), then
             by replica id — fully deterministic, so a fixed seed replays
             the same fleet schedule.
  tick       one fleet tick = one scheduler tick on every replica (live
             and draining), then retirement of drained replicas, then one
             autoscaler decision, then fleet metrics.
  scale up   a fresh replica from backend_factory starts taking traffic on
             the next submit.
  scale down the least-loaded live replica is marked DRAINING: it stops
             receiving new requests but keeps ticking until its wait queue
             and slot pool empty, then retires — scale-down never strands
             queued or in-flight work. Its EngineMetrics survive in
             `retired` for the roll-up.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.serve.api import ServeRequest, ServeResult
from repro.serve.fleet.autoscaler import Autoscaler
from repro.serve.fleet.metrics import FleetMetrics
from repro.serve.scheduler import Scheduler


class Replica:
    __slots__ = ("rid", "sched", "draining", "born_tick")

    def __init__(self, rid: int, sched: Scheduler, born_tick: int):
        self.rid = rid
        self.sched = sched
        self.draining = False
        self.born_tick = born_tick


class Router:
    def __init__(self, backend_factory: Callable[[], object], *,
                 replicas: int = 1,
                 max_queue: Optional[int] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 metrics: Optional[FleetMetrics] = None,
                 keep_results: bool = False):
        """``max_queue`` bounds each replica's wait queue (None = unbounded).
        ``keep_results`` additionally retains every ServeResult on
        self.results (the real-backend equivalence harness needs payloads;
        the million-request model replay must not)."""
        self._factory = backend_factory
        self._max_queue = max_queue
        self.autoscaler = autoscaler
        self.metrics = metrics or FleetMetrics()
        self.keep_results = keep_results
        self.results: List[ServeResult] = []
        self.replicas: Dict[int, Replica] = {}
        self.retired: Dict[int, Scheduler] = {}
        self.tick_no = 0
        self._next_rid = 0
        for _ in range(replicas):
            self._add_replica()

    # -- elasticity ----------------------------------------------------------
    def _sink(self, res: ServeResult) -> None:
        self.metrics.on_result(res)
        if self.keep_results:
            self.results.append(res)

    def _add_replica(self) -> Replica:
        rep = Replica(self._next_rid,
                      Scheduler(self._factory(), max_queue=self._max_queue,
                                result_sink=self._sink),
                      self.tick_no)
        self.replicas[rep.rid] = rep
        self._next_rid += 1
        return rep

    def _drain_replica(self, rep: Replica) -> None:
        rep.draining = True

    def live(self) -> List[Replica]:
        return [r for r in self.replicas.values() if not r.draining]

    @property
    def n_live(self) -> int:
        return len(self.live())

    def total_queued(self) -> int:
        return sum(r.sched.queued for r in self.replicas.values())

    def total_active(self) -> int:
        return sum(len(r.sched.active) for r in self.replicas.values())

    # -- dispatch ------------------------------------------------------------
    def _route_key(self, rep: Replica, req: Optional[ServeRequest] = None):
        # least queue depth; tie-break toward most deadline slack (earliest
        # queued deadline furthest in the future), then replica id. Slack is
        # measured against the REPLICA's tick clock: deadlines are absolute
        # in each scheduler's local time, and a replica spawned at fleet
        # tick t runs t ticks behind the fleet clock.
        #
        # Per-bucket depth accounting: when the replica serves a bucketed
        # backend (multi-resolution detection), the PRIMARY depth signal is
        # the queue depth in THIS request's bucket — a replica drowning in
        # 320s is still the right home for a 256 if its 256 page is idle.
        # The global depth stays as the next key, so non-bucketed backends
        # order exactly as before ((queued, queued, -slack, rid)).
        depth = rep.sched.queued
        bucket_of = getattr(rep.sched.backend, "bucket_of", None)
        if req is not None and bucket_of is not None:
            depth = rep.sched.queued_in_bucket(bucket_of(req))
        slack = rep.sched.earliest_deadline() - rep.sched.metrics.ticks
        return (depth, rep.sched.queued, -slack, rep.rid)

    def submit(self, req: ServeRequest) -> bool:
        target = min(self.live(), key=lambda rep: self._route_key(rep, req))
        return target.sched.submit(req)

    # -- one fleet tick ------------------------------------------------------
    def tick(self) -> None:
        for rep in list(self.replicas.values()):
            rep.sched.tick()
        self._retire_drained()
        if self.autoscaler is not None:
            self._apply_scale(self.autoscaler.decide(
                self.tick_no, [r.sched for r in self.live()]))
        self.metrics.record_tick(self.tick_no, self.n_live,
                                 self.total_queued())
        self.tick_no += 1

    def _retire_drained(self) -> None:
        for rep in [r for r in self.replicas.values() if r.draining]:
            sched = rep.sched
            if not sched.queued and not sched.active and not sched.queue:
                del self.replicas[rep.rid]
                self.retired[rep.rid] = sched
                self.metrics.record_scale(self.tick_no, "retired", rep.rid,
                                          self.n_live)

    def _apply_scale(self, delta: int) -> None:
        if delta > 0:
            rep = self._add_replica()
            self.metrics.record_scale(self.tick_no, "up", rep.rid,
                                      self.n_live)
        elif delta < 0:
            live = self.live()
            if len(live) <= 1:
                return                 # never drain the last live replica
            victim = min(live, key=lambda r: (r.sched.queued,
                                              len(r.sched.active), -r.rid))
            self._drain_replica(victim)
            self.metrics.record_scale(self.tick_no, "down", victim.rid,
                                      self.n_live)

    # -- driving -------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return any(r.sched.queue or r.sched.active
                   for r in self.replicas.values())

    def run(self, requests=None) -> List[ServeResult]:
        """Submit then tick until the whole fleet drains. Returns retained
        results when keep_results=True (else the FleetMetrics roll-up is
        the record)."""
        for req in requests or ():
            self.submit(req)
        self.drain()
        return self.results

    def drain(self, guard: int = 10**7) -> None:
        while self.busy:
            self.tick()
            guard -= 1
            if guard <= 0:
                raise RuntimeError("fleet failed to drain")

    def engine_summaries(self) -> Dict[int, dict]:
        """Per-replica EngineMetrics summaries, retired replicas included."""
        out = {rid: rep.sched.metrics.summary()
               for rid, rep in self.replicas.items()}
        out.update({rid: sched.metrics.summary()
                    for rid, sched in self.retired.items()})
        return out
