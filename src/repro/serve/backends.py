"""The two `serve.api.Backend` implementations.

`LMBackend` — autoregressive decode over the stage-stacked LM params: one
fused `decode_step` per tick for every pool row, batched multi-row prefill
at admission (requests arriving together prefill as one batch per prompt
length, then scatter into the pool via `cache.merge_rows`), per-row
temperature sampling. Two termination paths:

  * host-checked (default): the sampled token row syncs to the host every
    tick and the scheduler applies stop-token / max_new per emission;
  * ``done_mask=True``: the fused step (`engine.decode_step_donemask`)
    samples, appends to a device-side token buffer and folds the
    stop-token + max_new tests into a per-slot ``done`` bitmask — the only
    per-tick device→host read. Token sequences sync once, in bulk, when a
    slot finishes. Token-for-token equivalent to the host path (same
    sampler expressions, same PRNG-key discipline).

`DetectionBackend` — the paper's deployed workload: batched image requests
through the packed-W1A8 Pallas conv path + head decode + NMS, bundled into
ONE fixed-width jitted dispatch per resolution bucket. With ``depth=K`` the
backend keeps a K-deep in-flight dispatch window, generalizing how the FPGA
pipeline overlaps line-buffered conv with ingest: tick t's batch is
*dispatched* asynchronously and harvested up to K-1 ticks later — strictly
in dispatch order even when K>2 executables are in flight (completion
reordering via `DispatchWindow`) — so admission (host-side image staging,
slot assignment) and the next K-1 dispatches overlap device compute. The
slot pool widens (capacity = (K-1+buckets)·width, admit_width =
buckets·width) so full batches can stage while others are in flight —
steady state stays one batch per bucket per tick.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.config import _UNSET
from repro.models.layers import ModelConfig
from repro.serve import cache as cache_mod
from repro.serve.api import Emission, ServeRequest
from repro.serve.engine import decode_step, decode_step_donemask, prefill

# DetectionBackend's legacy kernel kwargs warn exactly once per process
# (the ServeEngine pattern); tests reset this to re-arm the warning.
_detect_kwargs_warned = False


def _warn_detect_kwargs_once() -> None:
    global _detect_kwargs_warned
    if _detect_kwargs_warned:
        return
    _detect_kwargs_warned = True
    import warnings
    warnings.warn(
        "DetectionBackend(interpret=/fuse_pool=) is deprecated; pass "
        "profile='tuned'|'default'|'interpret' instead",
        DeprecationWarning, stacklevel=3)


# The retired overlap flag warns exactly once per process (same pattern);
# tests reset this to re-arm the warning.
_detect_overlap_warned = False


def _warn_detect_overlap_once() -> None:
    global _detect_overlap_warned
    if _detect_overlap_warned:
        return
    _detect_overlap_warned = True
    import warnings
    warnings.warn(
        "DetectionBackend(overlap=) is deprecated; pass depth=K instead "
        "(overlap=True maps to depth=2, overlap=False to depth=1)",
        DeprecationWarning, stacklevel=3)


class DispatchWindow:
    """K-deep in-flight dispatch window with completion reordering.

    Batches push in dispatch order (each push takes a monotonically
    increasing ticket) and pop strictly in that order — an executable that
    finishes early still waits behind older in-flight work, so results
    surface to the scheduler in dispatch order regardless of completion
    order. `pop_due` implements the two-rule harvest schedule shared with
    the pure-python oracle in tests/test_serve_kdeep.py:

      * depth rule — after a tick's dispatches, at most ``depth - 1``
        batches stay resident; the oldest surplus batches block (harvest)
        now. depth=1 is single-shot (dispatch and block same tick);
        depth=2 is the classic double buffer.
      * drain rule — a tick that dispatched nothing harvests exactly one
        resident batch, so a drained queue surfaces trailing results one
        batch per tick (the double buffer's +1 drain tick, generalized).
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = int(depth)
        self._q: collections.deque = collections.deque()
        self._tickets = 0
        self._harvested = 0

    def __len__(self) -> int:
        return len(self._q)

    def push(self, item) -> int:
        ticket = self._tickets
        self._tickets += 1
        self._q.append((ticket, item))
        return ticket

    def pop_due(self, *, pushed: bool) -> list:
        due = []
        if not pushed and self._q:                 # drain rule
            due.append(self._pop())
        while len(self._q) >= self.depth:          # depth rule
            due.append(self._pop())
        return due

    def _pop(self):
        ticket, item = self._q.popleft()
        assert ticket == self._harvested, \
            "harvest must follow dispatch order"
        self._harvested = ticket + 1
        return item


class LMBackend:
    """Slot-pool LM decode backend (capacity = pool batch B)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, mode: str = "float", seed: int = 17,
                 done_mask: bool = False, max_stop_tokens: int = 4):
        self.cfg, self.params = cfg, params
        self.capacity, self.max_len, self.mode = slots, max_len, mode
        self.done_mask = done_mask
        self.cache = cache_mod.init_cache(cfg, slots, max_len)
        self.last_tok = jnp.zeros((slots,), jnp.int32)
        self.temp = np.zeros((slots,), np.float32)
        self._active = np.zeros((slots,), bool)
        self._emissions: Dict[int, List[Emission]] = collections.defaultdict(
            list)
        self._key = jax.random.PRNGKey(seed)
        self.host_syncs = 0          # per-tick step/harvest-path transfers
        self.host_sync_bytes = 0     # bytes over those transfers
        self.completion_syncs = 0    # bulk token fetches (done-mask path)
        if done_mask:
            self.max_stop_tokens = max_stop_tokens
            # device-side decode state (DESIGN.md §11 wire format)
            self.tok_buf = jnp.zeros((slots, max_len), jnp.int32)
            self.n_gen = jnp.zeros((slots,), jnp.int32)
            self.done = jnp.ones((slots,), bool)       # vacant rows are done
            # host mirrors — derivable from the admission record plus the
            # done-mask reads, so tracking them costs no extra transfers
            self._n_host = np.zeros((slots,), np.int64)
            self._done_host = np.ones((slots,), bool)
            self._stops_host: Dict[int, Tuple[int, ...]] = {}
            self._max_new_host = np.zeros((slots,), np.int64)
            self._stops_pad = np.full((slots, max_stop_tokens), -1, np.int32)
            self._step_done = jax.jit(
                lambda p, c, lt, tb, ng, dn, st, mn, t, k, use_key:
                decode_step_donemask(cfg, p, c, lt, tb, ng, dn, st, mn, t, k,
                                     mode=mode, use_key=use_key),
                static_argnums=(10,))
        else:
            self._step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t,
                                                             mode=mode))

    # -- admission: batched multi-row prefill --------------------------------
    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        by_len: Dict[int, list] = collections.defaultdict(list)
        for slot, req in assignments:
            by_len[len(req.prompt)].append((slot, req))
            self.temp[slot] = req.sampling.temperature
        for group in by_len.values():
            rows = [slot for slot, _ in group]
            prompts = jnp.asarray([list(r.prompt) for _, r in group],
                                  jnp.int32)
            logits, cache1 = prefill(self.cfg, self.params, prompts,
                                     max_len=self.max_len, mode=self.mode)
            self.cache = cache_mod.merge_rows(self.cache, cache1, rows)
            first = self._sample(logits, np.asarray(
                [r.sampling.temperature for _, r in group], np.float32))
            for i, (slot, req) in enumerate(group):
                tok = int(first[i])
                self.last_tok = self.last_tok.at[slot].set(tok)
                self._active[slot] = True
                if self.done_mask:
                    self._admit_done_mask(slot, req, tok)
                else:
                    self._emissions[slot].append(
                        Emission(kind="token", payload=tok))

    def _admit_done_mask(self, slot: int, req: ServeRequest,
                         tok: int) -> None:
        """Seed the device-side decode state for one admitted row. The
        prefill token is sampled host-side (shared path with host-checked
        mode), so its stop test runs here and folds into the initial done
        bit — a stop token in position 1 finishes the request this tick."""
        sp = req.sampling
        stops = tuple(sp.stop_tokens)
        if len(stops) > self.max_stop_tokens:
            raise ValueError(f"request {req.rid}: {len(stops)} stop tokens "
                             f"> backend cap {self.max_stop_tokens}")
        if sp.max_new > self.max_len:
            raise ValueError(f"request {req.rid}: max_new {sp.max_new} "
                             f"exceeds the device token buffer "
                             f"(max_len={self.max_len})")
        done0 = (tok in stops) or (1 >= sp.max_new)
        self.tok_buf = self.tok_buf.at[slot, 0].set(tok)
        self.n_gen = self.n_gen.at[slot].set(1)
        self.done = self.done.at[slot].set(done0)
        self._n_host[slot] = 1
        self._done_host[slot] = done0
        self._stops_host[slot] = stops
        self._max_new_host[slot] = sp.max_new
        self._stops_pad[slot] = -1
        self._stops_pad[slot, :len(stops)] = stops

    # -- one fused decode tick -----------------------------------------------
    def step(self) -> None:
        if not self._active.any():
            return
        if self.done_mask:
            self._step_done_mask()
            return
        logits, self.cache = self._step(self.params, self.cache,
                                        self.last_tok[:, None])
        nxt = self._sample(logits, self.temp)          # token-row host sync
        self.host_syncs += 1
        self.host_sync_bytes += 4 * self.capacity      # (B,) int32 tokens
        self.last_tok = jnp.asarray(nxt, jnp.int32)
        for slot in np.flatnonzero(self._active):
            self._emissions[int(slot)].append(
                Emission(kind="token", payload=int(nxt[slot])))

    def _step_done_mask(self) -> None:
        use_key = bool((self.temp > 0).any())          # same rule as _sample
        if use_key:
            self._key, k = jax.random.split(self._key)
        else:
            k = self._key                              # traced but unused
        (self.cache, self.last_tok, self.tok_buf, self.n_gen,
         self.done) = self._step_done(
            self.params, self.cache, self.last_tok, self.tok_buf, self.n_gen,
            self.done, jnp.asarray(self._stops_pad),
            jnp.asarray(self._max_new_host, jnp.int32),
            jnp.asarray(self.temp), k, use_key)
        # rows live at dispatch grew by one token (mirrors device n_gen)
        self._n_host += (self._active & ~self._done_host)

    def harvest(self) -> Dict[int, List[Emission]]:
        if not self.done_mask:
            out = dict(self._emissions)
            self._emissions = collections.defaultdict(list)
            return out
        out: Dict[int, List[Emission]] = {}
        if not self._active.any():
            return out
        done_np = np.asarray(self.done)          # THE per-tick bitmask read
        self.host_syncs += 1
        self.host_sync_bytes += self.capacity    # (B,) bool bitmask
        newly = done_np & self._active
        self._done_host = done_np.copy()
        if newly.any():
            rows = np.flatnonzero(newly)
            toks = np.asarray(self.tok_buf[jnp.asarray(rows)])  # one gather
            self.completion_syncs += 1
            for i, slot in enumerate(rows):
                slot = int(slot)
                n = int(self._n_host[slot])
                seq = tuple(int(t) for t in toks[i, :n])
                reason = ("stop" if seq and seq[-1]
                          in self._stops_host.get(slot, ()) else "length")
                out[slot] = [Emission(kind="tokens", payload=seq,
                                      finish=reason, final=True)]
        return out

    def release(self, slot: int) -> None:
        self._active[slot] = False
        self.temp[slot] = 0.0        # stale temp would force sampling forever
        self._emissions.pop(slot, None)
        if self.done_mask:
            self.done = self.done.at[slot].set(True)
            self._done_host[slot] = True
            self._stops_host.pop(slot, None)

    # per-row temperature: greedy rows take argmax, sampled rows categorical
    def _sample(self, logits, temp) -> np.ndarray:
        greedy = jnp.argmax(logits, -1)
        t = np.asarray(temp, np.float32)
        if not (t > 0).any():
            return np.asarray(greedy, np.int32)
        self._key, k = jax.random.split(self._key)
        scaled = logits / jnp.maximum(jnp.asarray(t), 1e-6)[:, None]
        sampled = jax.random.categorical(k, scaled, -1)
        return np.asarray(jnp.where(jnp.asarray(t) > 0, sampled, greedy),
                          np.int32)


class DetectionBackend:
    """Packed-W1A8 YOLO detection backend (one image per request).

    ``art`` is a `models.yolo.deploy_yolo_kernel` artifact; images are
    (S, S, 3) float in [0, 1] or uint8 raw pixels (divided by 256, the
    Q0.8 convention), where S is one of the configured resolution
    ``buckets`` (default: the artifact's buckets, else 320). Emissions
    carry NMS'd detections plus the raw head for verification against the
    float reference (core.verify).

    The forward (Pallas convs → head decode → NMS) is ONE jitted dispatch
    at a fixed batch width (= ``slots``) **per bucket** — all buckets share
    the packed weights and the jit cache holds one fixed-width executable
    per image size, the way `spawn()` shares one executable across
    replicas. Partial batches zero-pad so every tick reuses the same
    executable. ``depth=K`` keeps up to K dispatches in flight, harvested
    strictly in dispatch order (see module docstring / `DispatchWindow`);
    ``depth=2`` is the retired ``overlap=True`` double buffer.

    Kernel launch configuration comes from ``profile``
    (`models.yolo.PROFILES`): ``"tuned"`` — the serving default — resolves
    per-layer winners from the committed autotune table (which is where
    ``fuse_pool=True`` became the default for pool layers, it wins on the
    table); ``"interpret"`` reproduces the historical heuristic/interpret
    behavior; ``"default"`` is heuristics with backend-resolved compile
    mode. The old raw kernel kwargs (``interpret=``, ``fuse_pool=``)
    survive one release behind a DeprecationWarning and force the
    equivalent profile override.

    ``device_nms=True`` changes the emission wire, not the math: the NMS
    always runs inside the one executable, but the default wire still ships
    the raw (G, G, 75) f32 head alongside it for verification. Device-NMS
    mode ships only the final compact detection set per image — fp16 boxes
    (max_out, 4) + fp16 scores + int8 classes + one int32 valid-count
    (`models.detection.compact_detections`) — cutting the per-dispatch
    device→host payload ~56× for the default head geometry.

    Host-sync accounting: the per-dispatch payload is STATIC (fixed-width
    executable per bucket ⇒ `jax.eval_shape` at construction), so syncs and
    bytes are credited at the tick that *dispatches* a batch, not the tick
    whose harvest happens to block on it. K-deep mode therefore shows the
    same per-tick byte attribution as single-shot (its extra drain ticks
    cost 0) instead of charging tick t with an older tick's bytes.
    """

    def __init__(self, art: dict, *, slots: int = 4, profile: str = None,
                 depth: Optional[int] = None, overlap=_UNSET,
                 device_nms: bool = False,
                 buckets: Optional[Sequence[int]] = None,
                 iou_thresh: float = 0.45, score_thresh: float = 0.25,
                 max_out: int = 50, interpret=_UNSET, fuse_pool=_UNSET):
        from repro.models import detection, yolo
        overrides = {}
        if interpret is not _UNSET or fuse_pool is not _UNSET:
            if profile is not None:
                raise TypeError("pass either profile= or the legacy "
                                "interpret=/fuse_pool= kwargs, not both")
            _warn_detect_kwargs_once()
            profile = "interpret"            # the historical default regime
            if interpret is not _UNSET:
                overrides["interpret"] = interpret
            if fuse_pool is not _UNSET:
                overrides["fuse_pool"] = fuse_pool
        if overlap is not _UNSET:
            if depth is not None:
                raise TypeError("pass either depth= or the legacy overlap= "
                                "flag, not both")
            _warn_detect_overlap_once()
            depth = 2 if overlap else 1
        if depth is None:
            depth = 1
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if profile is None:
            profile = "tuned"
        if profile not in yolo.PROFILES:
            raise ValueError(
                f"profile must be one of {yolo.PROFILES}, got {profile!r}")
        if buckets is None:
            buckets = art.get("buckets") or (yolo.INPUT_SIZE,)
        self.buckets = tuple(dict.fromkeys(int(b) for b in buckets))
        for b in self.buckets:
            if b <= 0 or b % 32:
                raise ValueError(f"bucket sizes must be positive multiples "
                                 f"of 32 (5 pools), got {b}")
        self.art = art
        self.width = slots                        # device batch per dispatch
        self.depth = depth                        # K-deep dispatch window
        self.capacity = (depth - 1 + len(self.buckets)) * slots
        self.admit_width = len(self.buckets) * slots
        self.bucket_admit_width = slots           # per-bucket page per tick
        self.profile = profile
        self.device_nms = device_nms
        self.post = dict(iou_thresh=iou_thresh, score_thresh=score_thresh,
                         max_out=max_out)
        # per-bucket staging (insertion-ordered: dispatch order is the
        # order buckets first staged this tick)
        self._staged: Dict[int, List[Tuple[int, ServeRequest]]] = {}
        self._window = DispatchWindow(depth)
        self._emissions: Dict[int, List[Emission]] = {}
        self.host_syncs = 0
        self.host_sync_bytes = 0
        self.completion_syncs = 0

        def _bundle(imgs):
            raw = yolo.yolo_forward_kernel(art, imgs, profile=profile,
                                           **overrides)
            boxes, scores, classes = detection.postprocess(raw, **self.post)
            if device_nms:                        # compact emission wire only
                return jax.vmap(detection.compact_detections)(boxes, scores,
                                                              classes)
            return raw, boxes, scores, classes

        # ONE jit, traced once per bucket shape: the jit cache is the
        # per-bucket executable table, and every executable closes over the
        # same packed weights (no per-bucket model fork)
        self._fwd = jax.jit(_bundle)
        # the dispatch payload is static — one fixed-width executable per
        # bucket — so its byte cost is known without transferring anything
        self._batch_bytes = {
            b: sum(int(np.prod(o.shape)) * o.dtype.itemsize
                   for o in jax.tree_util.tree_leaves(jax.eval_shape(
                       self._fwd, jax.ShapeDtypeStruct(
                           (self.width, b, b, 3), jnp.float32))))
            for b in self.buckets}

    def spawn(self, *, depth: Optional[int] = None) -> "DetectionBackend":
        """Fresh replica of this backend for the fleet router: independent
        slot/emission/sync state, SHARING the compiled fixed-width
        executable (the program is stateless; the pool is not). One
        warmup() on the template covers every spawned replica, so router
        scale-up costs no recompile. ``depth`` re-sizes the replica's
        dispatch window (and slot pool) without recompiling — how the
        BENCH_serve K-saturation sweep reuses one executable across K."""
        import copy
        twin = copy.copy(self)
        if depth is not None:
            if depth < 1:
                raise ValueError(f"depth must be >= 1, got {depth}")
            twin.depth = int(depth)
            twin.capacity = (twin.depth - 1 + len(self.buckets)) * self.width
        twin._staged = {}
        twin._window = DispatchWindow(twin.depth)
        twin._emissions = {}
        twin.host_syncs = 0
        twin.host_sync_bytes = 0
        twin.completion_syncs = 0
        return twin

    def bucket_of(self, req: ServeRequest) -> int:
        """Resolution bucket (= image side S) for a request — the scheduler
        packs per-bucket batches off this, the router depth-accounts on it.
        Reads only the static `image_shape`, never the pixels."""
        shape = getattr(req, "image_shape", None)
        if shape is None and req.image is not None:
            shape = np.shape(req.image)
        if not shape:
            raise ValueError(f"request {req.rid}: detection needs an image")
        size = int(shape[0])
        if size not in self._batch_bytes:
            raise ValueError(
                f"request {req.rid}: image size {size} matches no "
                f"configured bucket {self.buckets}")
        return size

    def warmup(self) -> None:
        """Compile + run every bucket's fixed-width bundle once so serving
        ticks (and the per-K comparison in BENCH_serve) exclude trace
        time."""
        for b in self.buckets:
            z = jnp.zeros((self.width, b, b, 3), jnp.float32)
            jax.block_until_ready(self._fwd(z))

    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        for slot, req in assignments:
            self._staged.setdefault(self.bucket_of(req), []).append(
                (slot, req))

    def step(self) -> None:
        staged, self._staged = self._staged, {}
        pushed = 0
        for bucket, group in staged.items():
            imgs = jnp.stack([self._to_float(r.image) for _, r in group])
            if imgs.shape[0] < self.width:       # fixed-width executable
                imgs = jnp.pad(imgs, ((0, self.width - imgs.shape[0]),
                                      (0, 0), (0, 0), (0, 0)))
            self._window.push(([slot for slot, _ in group],
                               self._fwd(imgs)))  # async dispatch
            pushed += 1
            # credit the transfer to the tick that dispatched the batch —
            # the payload width is static, the harvest tick is a schedule
            # detail (a K-deep window blocks up to K-1 ticks later; the
            # bytes are the same)
            self.host_syncs += 1
            self.host_sync_bytes += self._batch_bytes[bucket]
        # harvest in dispatch order: everything beyond depth-1 resident
        # batches, or one batch on a drain (no-dispatch) tick
        for inflight in self._window.pop_due(pushed=bool(pushed)):
            self._emit(inflight)

    def _emit(self, inflight: tuple) -> None:
        slots_, results = inflight
        if self.device_nms:
            boxes, scores, classes, valid = jax.device_get(results)
            for i, slot in enumerate(slots_):
                # upcast host-side (lossless); the fp16/int8 forms above are
                # what crossed the wire and what _batch_bytes counted
                payload = {"boxes": np.asarray(boxes[i], np.float32),
                           "scores": np.asarray(scores[i], np.float32),
                           "classes": np.asarray(classes[i], np.int32),
                           "valid": int(valid[i])}
                self._emissions.setdefault(slot, []).append(
                    Emission(kind="detections", payload=payload, final=True))
            return
        raw, boxes, scores, classes = jax.device_get(results)  # one transfer
        for i, slot in enumerate(slots_):
            payload = {"boxes": np.asarray(boxes[i]),
                       "scores": np.asarray(scores[i]),
                       "classes": np.asarray(classes[i]),
                       "raw": np.asarray(raw[i])}
            self._emissions.setdefault(slot, []).append(
                Emission(kind="raw_head", payload=payload, final=True))

    def harvest(self) -> Dict[int, List[Emission]]:
        out, self._emissions = self._emissions, {}
        return out

    def release(self, slot: int) -> None:
        self._emissions.pop(slot, None)

    @staticmethod
    def _to_float(image) -> jax.Array:
        img = jnp.asarray(image)
        if img.dtype == jnp.uint8:
            img = img.astype(jnp.float32) / 256.0
        return img.astype(jnp.float32)
