"""The two `serve.api.Backend` implementations.

`LMBackend` — autoregressive decode over the stage-stacked LM params: one
fused `decode_step` per tick for every pool row, batched multi-row prefill
at admission (requests arriving together prefill as one batch per prompt
length, then scatter into the pool via `cache.merge_rows` — no per-leaf
shape-matched splice), per-row temperature sampling.

`DetectionBackend` — the paper's deployed workload: batched 320×320 image
requests through the packed-W1A8 Pallas conv path
(`models.yolo.yolo_forward_kernel`), detection-head decode + NMS
(`models.detection.postprocess`). Every admitted image completes in the
tick after admission (single-shot inference), so slots recycle every tick
under load.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ModelConfig
from repro.serve import cache as cache_mod
from repro.serve.api import Emission, ServeRequest
from repro.serve.engine import decode_step, prefill


class LMBackend:
    """Slot-pool LM decode backend (capacity = pool batch B)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, mode: str = "float", seed: int = 17):
        self.cfg, self.params = cfg, params
        self.capacity, self.max_len, self.mode = slots, max_len, mode
        self.cache = cache_mod.init_cache(cfg, slots, max_len)
        self.last_tok = jnp.zeros((slots,), jnp.int32)
        self.temp = np.zeros((slots,), np.float32)
        self._active = np.zeros((slots,), bool)
        self._emissions: Dict[int, List[Emission]] = collections.defaultdict(
            list)
        self._step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t,
                                                         mode=mode))
        self._key = jax.random.PRNGKey(seed)

    # -- admission: batched multi-row prefill --------------------------------
    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        by_len: Dict[int, list] = collections.defaultdict(list)
        for slot, req in assignments:
            by_len[len(req.prompt)].append((slot, req))
            self.temp[slot] = req.sampling.temperature
        for group in by_len.values():
            rows = [slot for slot, _ in group]
            prompts = jnp.asarray([list(r.prompt) for _, r in group],
                                  jnp.int32)
            logits, cache1 = prefill(self.cfg, self.params, prompts,
                                     max_len=self.max_len, mode=self.mode)
            self.cache = cache_mod.merge_rows(self.cache, cache1, rows)
            first = self._sample(logits, np.asarray(
                [r.sampling.temperature for _, r in group], np.float32))
            for i, slot in enumerate(rows):
                tok = int(first[i])
                self.last_tok = self.last_tok.at[slot].set(tok)
                self._active[slot] = True
                self._emissions[slot].append(Emission(token=tok))

    # -- one fused decode tick -----------------------------------------------
    def step(self) -> None:
        if not self._active.any():
            return
        logits, self.cache = self._step(self.params, self.cache,
                                        self.last_tok[:, None])
        nxt = self._sample(logits, self.temp)
        self.last_tok = jnp.asarray(nxt, jnp.int32)
        for slot in np.flatnonzero(self._active):
            self._emissions[int(slot)].append(Emission(token=int(nxt[slot])))

    def harvest(self) -> Dict[int, List[Emission]]:
        out = dict(self._emissions)
        self._emissions = collections.defaultdict(list)
        return out

    def release(self, slot: int) -> None:
        self._active[slot] = False
        self.temp[slot] = 0.0        # stale temp would force sampling forever
        self._emissions.pop(slot, None)

    # per-row temperature: greedy rows take argmax, sampled rows categorical
    def _sample(self, logits, temp) -> np.ndarray:
        greedy = jnp.argmax(logits, -1)
        t = np.asarray(temp, np.float32)
        if not (t > 0).any():
            return np.asarray(greedy, np.int32)
        self._key, k = jax.random.split(self._key)
        scaled = logits / jnp.maximum(jnp.asarray(t), 1e-6)[:, None]
        sampled = jax.random.categorical(k, scaled, -1)
        return np.asarray(jnp.where(jnp.asarray(t) > 0, sampled, greedy),
                          np.int32)


class DetectionBackend:
    """Packed-W1A8 YOLO detection backend (single-shot per request).

    ``art`` is a `models.yolo.deploy_yolo_kernel` artifact; images are
    (320, 320, 3) float in [0, 1] or uint8 raw pixels (divided by 256, the
    Q0.8 convention). Emissions carry NMS'd detections plus the raw head
    for verification against the float reference (core.verify).
    """

    def __init__(self, art: dict, *, slots: int = 4, interpret: bool = True,
                 iou_thresh: float = 0.45, score_thresh: float = 0.25,
                 max_out: int = 50):
        self.art = art
        self.capacity = slots
        self.interpret = interpret
        self.post = dict(iou_thresh=iou_thresh, score_thresh=score_thresh,
                         max_out=max_out)
        self._staged: List[Tuple[int, ServeRequest]] = []
        self._emissions: Dict[int, List[Emission]] = {}

    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        self._staged.extend(assignments)

    def step(self) -> None:
        if not self._staged:
            return
        from repro.models import detection, yolo
        imgs = jnp.stack([self._to_float(r.image) for _, r in self._staged])
        raw = yolo.yolo_forward_kernel(self.art, imgs,
                                       interpret=self.interpret)
        boxes, scores, classes = detection.postprocess(raw, **self.post)
        for i, (slot, _) in enumerate(self._staged):
            payload = {"boxes": np.asarray(boxes[i]),
                       "scores": np.asarray(scores[i]),
                       "classes": np.asarray(classes[i]),
                       "raw": np.asarray(raw[i])}
            self._emissions.setdefault(slot, []).append(
                Emission(payload=payload, final=True))
        self._staged = []

    def harvest(self) -> Dict[int, List[Emission]]:
        out, self._emissions = self._emissions, {}
        return out

    def release(self, slot: int) -> None:
        self._emissions.pop(slot, None)

    @staticmethod
    def _to_float(image) -> jax.Array:
        img = jnp.asarray(image)
        if img.dtype == jnp.uint8:
            img = img.astype(jnp.float32) / 256.0
        return img.astype(jnp.float32)
