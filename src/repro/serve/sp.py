"""Context / sequence parallelism (SP) for long-context decode.

For long_500k cells the KV cache shards over the "data" axis on the
*sequence* dim (each of the 16 data shards holds 32k of the 512k context).
One decode step computes a local partial softmax per shard and combines with
the global log-sum-exp trick:

    m = pmax(m_i);  l = psum(l_i·e^{m_i−m});  o = psum(o_i·e^{m_i−m}) / l

— one scalar-sized psum pair per layer instead of gathering 512k of KV.
Used by the jamba long_500k cell (its 9 attention layers); mamba needs no SP
(O(1) state) and mixtral's SWA ring cache is window-bounded.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401  (jax.shard_map shim on older jax)


def sp_attention_local(q, k_local, v_local, pos_local, cur_pos):
    """Partial attention of one shard. q (B,H,hd); k/v (B,T_l,KV,hd);
    pos_local (B,T_l) global positions; cur_pos (B,).
    Returns (o (B,H,hd), m (B,H), l (B,H))."""
    b, h, hd = q.shape
    kv = k_local.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_local) / jnp.sqrt(hd)
    logits = logits.astype(jnp.float32)
    valid = pos_local <= cur_pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                            # (B,KV,G)
    e = jnp.exp(logits - m[..., None])
    e = jnp.where(jnp.isfinite(logits), e, 0.0)
    l = jnp.sum(e, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", e.astype(v_local.dtype), v_local)
    return (o.reshape(b, h, hd), m.reshape(b, h), l.reshape(b, h))


def sp_combine(o, m, l, axis: str):
    """Global log-sum-exp combine across the SP axis."""
    m_glob = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis)
    o_glob = jax.lax.psum(o * corr[..., None].astype(o.dtype), axis)
    return o_glob / jnp.maximum(l_glob, 1e-20)[..., None].astype(o.dtype)


def sp_decode_attention(mesh, axis: str, q, k_sh, v_sh, pos_sh, cur_pos):
    """shard_map wrapper: q (B,H,hd) replicated; k/v (B,T,KV,hd) sharded on
    T over `axis`; pos (B,T) sharded likewise. Returns (B,H,hd)."""
    def inner(q, k, v, p, cp):
        o, m, l = sp_attention_local(q, k, v, p, cp)
        return sp_combine(o, m, l, axis)

    return jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None),
                  P(None, axis), P()),
        out_specs=P(), check_vma=False)(q, k_sh, v_sh, pos_sh, cur_pos)
