"""KV / SSM decode caches with static shapes (slot-based batching).

Layout: one cache entry per layer-slot, stacked over stages like the params
(consumed by the same lax.scan). Attention caches are (stages, B, S_max,
KV, hd) ×2; mamba caches are the O(1) recurrent states. Per-row `lengths`
(B,) drive causal masking, so rows at different positions coexist in one
batch (continuous batching).

Sharding: batch over DP axes, kv-heads over "model" when divisible; for the
long_500k cells the KV sequence dim shards over "data" instead (context /
sequence parallelism — see serve.sp_attention).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import mamba as mb
from repro.models.layers import ModelConfig


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    """Cache pytree: {'slots': tuple per period-slot, 'lengths': (B,)}."""
    n_stages = cfg.num_layers // cfg.period
    slots = []
    for i in range(cfg.period):
        kind = cfg.mixer_kind(i)
        if kind.startswith("attn"):
            shape = (n_stages, batch, max_len, cfg.num_kv_heads, cfg.hd)
            slots.append({"k": jnp.zeros(shape, dtype),
                          "v": jnp.zeros(shape, dtype)})
        else:
            one = mb.init_mamba_cache(cfg, batch, dtype)
            slots.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_stages,) + x.shape)
                .copy() if hasattr(x, "shape") else x, one))
    cache = {"slots": tuple(slots),
             "lengths": jnp.zeros((batch,), jnp.int32)}
    if cfg.encoder_layers:
        cache["enc_out"] = jnp.zeros((batch, max_len, cfg.d_model), dtype)
    return cache


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                bytes_per_el: int = 4) -> int:
    leaves = jax.tree_util.tree_leaves(jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len)))
    return sum(int(jnp.prod(jnp.asarray(l.shape))) * bytes_per_el
               for l in leaves)
