"""KV / SSM decode caches with static shapes — the single cache module.

Layout: one cache entry per layer-slot, stacked over stages like the params
(consumed by the same lax.scan). Attention caches are **ring buffers**
(stages, B, L, KV, hd) ×2 plus a `pos` plane recording the absolute position
written at each ring slot; L = min(max_len, sliding_window) for windowed
archs, so mixtral's long_500k decode keeps 4096 slots/layer instead of
524288 (128× cache memory — DESIGN.md §5). Mamba caches are the O(1)
recurrent states. Per-row `lengths` (B,) drive causal masking, so rows at
different positions coexist in one batch (continuous batching).

Every leaf under ``cache["slots"]`` carries the batch on axis 1 (after the
stage-stacking axis) and ``cache["lengths"]`` on axis 0 — `merge_rows`
relies on that invariant to scatter freshly prefilled rows into the serving
pool without per-leaf shape guessing (the bug surface of the old splice).

Sharding: batch over DP axes, kv-heads over "model" when divisible; for the
long_500k cells the KV sequence dim shards over "data" instead (context /
sequence parallelism — see serve.sp).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import mamba as mb
from repro.models.layers import ModelConfig

# Unwritten ring slots carry this sentinel position: always masked out by the
# `pc <= pos` validity test in engine._attn_decode.
BIGPOS = jnp.int32(2 ** 30)


def _attn_cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    window = 0
    if kind == "attn_local" or (cfg.sliding_window and not cfg.local_global):
        window = cfg.sliding_window
    return min(max_len, window) if window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    """Cache pytree: {'slots': tuple per period-slot, 'lengths': (B,)}."""
    n_stages = cfg.num_layers // cfg.period
    slots = []
    for i in range(cfg.period):
        kind = cfg.mixer_kind(i)
        if kind.startswith("attn"):
            length = _attn_cache_len(cfg, kind, max_len)
            shape = (n_stages, batch, length, cfg.num_kv_heads, cfg.hd)
            slots.append({"k": jnp.zeros(shape, dtype),
                          "v": jnp.zeros(shape, dtype),
                          "pos": jnp.full((n_stages, batch, length), BIGPOS)})
        else:
            one = mb.init_mamba_cache(cfg, batch, dtype)
            slots.append(jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_stages,) + x.shape, x.dtype), one))
    return {"slots": tuple(slots),
            "lengths": jnp.zeros((batch,), jnp.int32)}


def merge_rows(pool: dict, new: dict, rows: Sequence[int]) -> dict:
    """Scatter rows of a freshly prefilled cache into the serving pool.

    ``new`` is an init_cache/prefill cache of batch k; ``rows`` names the k
    pool rows (slots) to overwrite. Uses the structural invariant above —
    batch axis 1 under "slots", axis 0 for "lengths" — instead of matching
    leaves by shape.
    """
    idx = jnp.asarray(rows, jnp.int32)

    def scatter(p, n):
        return p.at[:, idx].set(n.astype(p.dtype))

    slots = tuple(jax.tree_util.tree_map(scatter, pc, nc)
                  for pc, nc in zip(pool["slots"], new["slots"]))
    return {"slots": slots,
            "lengths": pool["lengths"].at[idx].set(new["lengths"])}


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                bytes_per_el: int = 4) -> int:
    leaves = jax.tree_util.tree_leaves(jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len)))
    return sum(int(jnp.prod(jnp.asarray(l.shape))) * bytes_per_el
               for l in leaves)
