"""Slot-based continuous batching — the request-level serving loop.

A fixed pool of B slots runs one fused decode_step per tick; requests join
any free slot (their prompt prefilled into that row's cache lines) and leave
when finished, without stalling other rows. Per-row `lengths` make the
attention masks correct across heterogeneous positions.

Row-wise prefill uses a B=1 prefill + cache splice; production would batch
prefills, but the splice keeps the engine simple and exactly correct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from repro.serve.engine import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, mode: str = "float",
                 temperature: float = 0.0):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.mode = slots, max_len, mode
        self.temperature = temperature
        self.cache = init_cache(cfg, slots, max_len)
        self.active: Dict[int, Request] = {}      # slot → request
        self.last_tok = jnp.zeros((slots,), jnp.int32)
        self._step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t,
                                                         mode=mode))
        self._key = jax.random.PRNGKey(17)

    # -- request admission ---------------------------------------------------
    def add_request(self, req: Request) -> bool:
        free = [s for s in range(self.slots) if s not in self.active]
        if not free:
            return False
        slot = free[0]
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = prefill(self.cfg, self.params, prompt,
                                 max_len=self.max_len, mode=self.mode)
        # splice row `slot` of the pool cache from the B=1 prefill cache
        def splice(pool, one):
            return pool.at[:, slot] .set(one[:, 0]) \
                if pool.ndim >= 2 and pool.shape[1] == self.slots else pool
        new_slots = []
        for pool_c, one_c in zip(self.cache["slots"], cache1["slots"]):
            new_slots.append(jax.tree_util.tree_map(splice, pool_c, one_c))
        self.cache = {"slots": tuple(new_slots),
                      "lengths": self.cache["lengths"].at[slot]
                      .set(prompt.shape[1])}
        self.last_tok = self.last_tok.at[slot].set(
            int(jnp.argmax(logits[0])))
        self.active[slot] = req
        return True

    # -- one decode tick -----------------------------------------------------
    def step(self):
        if not self.active:
            return
        for slot, req in self.active.items():
            req.out.append(int(self.last_tok[slot]))
        logits, self.cache = self._step(self.params, self.cache,
                                        self.last_tok[:, None])
        if self.temperature > 0:
            self._key, k = jax.random.split(self._key)
            nxt = jax.random.categorical(k, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        self.last_tok = nxt.astype(jnp.int32)
        for slot in list(self.active):
            req = self.active[slot]
            if len(req.out) >= req.max_new:
                req.done = True
                del self.active[slot]

    def run(self, requests: List[Request]):
        queue = list(requests)
        while queue or self.active:
            while queue and self.add_request(queue[0]):
                queue.pop(0)
            self.step()
        return requests
