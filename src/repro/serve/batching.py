"""Deprecated serve-v1 surface, kept importable for existing callers.

`ServeEngine` / `Request` now delegate to the v2 stack
(`serve.api` + `serve.scheduler` + `serve.backends.LMBackend`); new code
should use those directly — see DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List

from repro.models.layers import ModelConfig
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.backends import LMBackend
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


# ServeEngine warns exactly once per process, not once per construction — a
# server building one engine per request-pool otherwise re-warns on every
# pool spin-up. Tests reset this to re-arm the warning.
_deprecation_warned = False


def _warn_deprecated_once() -> None:
    global _deprecation_warned
    if _deprecation_warned:
        return
    _deprecation_warned = True
    warnings.warn("serve.batching.ServeEngine is deprecated; use "
                  "serve.Scheduler with serve.LMBackend",
                  DeprecationWarning, stacklevel=3)


class ServeEngine:
    """Deprecated: thin shim over Scheduler + LMBackend (one global
    temperature, no stop tokens — the v1 feature set)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, mode: str = "float",
                 temperature: float = 0.0):
        _warn_deprecated_once()
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.mode = slots, max_len, mode
        self.temperature = temperature
        self.backend = LMBackend(cfg, params, slots=slots, max_len=max_len,
                                 mode=mode)
        self.scheduler = Scheduler(self.backend)
        self._by_rid = {}

    @property
    def active(self):
        """v1 view: slot → the caller's Request (token stream on .out)."""
        return {slot: self._by_rid[rec.req.rid]
                for slot, rec in self.scheduler.active.items()}

    def add_request(self, req: Request) -> bool:
        if not self.scheduler.free:
            return False
        self._by_rid[req.rid] = req
        self.scheduler.submit(ServeRequest(
            rid=req.rid, prompt=req.prompt,
            sampling=SamplingParams(max_new=req.max_new,
                                    temperature=self.temperature)))
        self.scheduler.admit()
        return True

    def step(self):
        if not self.scheduler.active:
            return
        self.scheduler.step_harvest()
        self._sync()

    def run(self, requests: List[Request]):
        queue = list(requests)
        while queue or self.scheduler.active:
            while queue and self.add_request(queue[0]):
                queue.pop(0)
            self.step()
        self._sync()
        return requests

    def _sync(self):
        # Mid-flight .out streams like v1 but may run one token ahead: the
        # prefill token and the first decode token land in the same step()
        # harvest here, where v1 surfaced them on consecutive steps. Final
        # token lists are identical.
        for rec in self.scheduler.active.values():
            req = self._by_rid.get(rec.req.rid)
            if req is not None:
                req.out = list(rec.tokens)
        for res in self.scheduler.results:
            req = self._by_rid.get(res.rid)
            if req is not None and not req.done:
                req.out = list(res.tokens)
                req.done = True
