"""Request scheduler — paged admission, deadlines, priorities, metrics.

One `tick` = admit (expire overdue waiters, then fill free slots from the
bounded wait queue — at most `backend.admit_width` requests globally and
`backend.bucket_admit_width` per resolution bucket, one batched
backend.admit call) → backend.step (one fused compute tick; a K-deep
streaming backend dispatches tick t here and surfaces its results up to
K-1 ticks later, in dispatch order) → harvest (ingest kind-tagged
emissions in order, finish requests on stop-token / max_new /
final-payload / bulk finish, drop in-flight work that overran its
completion deadline, recycle slots).

Admission order is **(priority, deadline, arrival-seq)**: the queue pops the
smallest triple, so lower `ServeRequest.priority` classes admit strictly
first, and *within* one class ordering stays EDF with FIFO tie-break —
deadline-free priority-0 traffic is byte-identical to the pre-priority
scheduler. The wait queue is bounded (`max_queue`): a submit into a full
queue is rejected immediately (finish_reason "rejected"); a waiter whose
admission deadline passes before a slot frees expires (finish_reason
"expired"); an admitted request that overruns
`ServeRequest.completion_deadline_ticks` is dropped at harvest (finish
reason "expired", counted separately as `expired_inflight` — its slot
recycles, late backend emissions for it are ignored). A burst is always
fully accounted: completed + rejected + expired + expired_inflight =
submitted.

Because priority reorders the admission heap, deadline expiry runs off a
*separate* min-heap keyed by absolute deadline with lazy deletion: both
heaps hold only (key..., seq) and `_waiting[seq]` is the single source of
liveness — admitting or expiring a seq removes it from `_waiting`, and
stale heap entries are skipped (and pruned from the head) when popped.

Invariants:
  * a slot is in exactly one of {free, active} between ticks;
  * every waiting request's seq is in `_waiting` and on the admission heap;
  * emissions for one slot are ingested in emission order, and everything
    after the finishing emission is dropped (a fused decode tick may
    overrun a request's stop condition by one token);
  * the wait queue drains to empty whenever the backend has capacity and
    requests have no (or generous) deadlines.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional

from repro.serve.api import (Backend, EngineMetrics, ServeRequest,
                             ServeResult)

_NO_DEADLINE = float("inf")


@dataclasses.dataclass
class _Active:
    req: ServeRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    payload: Optional[dict] = None
    admitted_tick: int = 0
    wait_ticks: int = 0
    complete_by: float = _NO_DEADLINE   # last tick index allowed to finish


class Scheduler:
    def __init__(self, backend: Backend, *,
                 max_queue: Optional[int] = None,
                 metrics: Optional[EngineMetrics] = None,
                 result_sink: Optional[Callable[[ServeResult], None]] = None):
        self.backend = backend
        self.metrics = metrics or EngineMetrics(capacity=backend.capacity)
        self.metrics.capacity = backend.capacity
        # admission heap of (priority, abs_deadline, seq); expiry heap of
        # (abs_deadline, seq); _waiting[seq] = (req, submit_tick) is liveness
        self.queue: List[tuple] = []
        self._deadlines: List[tuple] = []
        self._waiting: Dict[int, tuple] = {}
        self.max_queue = max_queue
        self.free: List[int] = list(range(backend.capacity))
        self.active: Dict[int, _Active] = {}
        # results accumulate here unless a sink consumes them (the fleet
        # router streams millions of results through FleetMetrics without
        # holding them all live)
        self.results: List[ServeResult] = []
        self._sink = result_sink
        self._seq = 0
        # syncs already on the backend's counters (e.g. a warmup pass) are
        # not this scheduler's to credit
        self._synced = getattr(backend, "host_syncs", 0)
        self._synced_bytes = getattr(backend, "host_sync_bytes", 0)
        self._completion_synced = getattr(backend, "completion_syncs", 0)

    # -- introspection (the fleet router routes on these) --------------------
    @property
    def queued(self) -> int:
        """Live wait-queue depth (stale heap entries excluded)."""
        return len(self._waiting)

    def queued_in_bucket(self, bucket) -> int:
        """Live wait-queue depth restricted to one resolution bucket — the
        fleet router's per-bucket depth signal. Falls back to the global
        depth when the backend is not bucketed."""
        bucket_of = getattr(self.backend, "bucket_of", None)
        if bucket_of is None:
            return len(self._waiting)
        return sum(1 for req, _ in self._waiting.values()
                   if bucket_of(req) == bucket)

    def earliest_deadline(self) -> float:
        """Earliest absolute admission deadline still waiting (inf when the
        queue holds no deadlined request) — the router's slack signal."""
        while self._deadlines and self._deadlines[0][1] not in self._waiting:
            heapq.heappop(self._deadlines)
        return self._deadlines[0][0] if self._deadlines else _NO_DEADLINE

    def _emit_result(self, res: ServeResult) -> None:
        if self._sink is not None:
            self._sink(res)
        else:
            self.results.append(res)

    # -- submission ----------------------------------------------------------
    def submit(self, req: ServeRequest) -> bool:
        """Queue a request. Returns False (and surfaces a "rejected" result)
        when the bounded wait queue is full."""
        self.metrics.submitted += 1
        if self.max_queue is not None and len(self._waiting) >= self.max_queue:
            self.metrics.rejected += 1
            self._emit_result(ServeResult(
                rid=req.rid, finish_reason="rejected",
                deadline_met=(False if req.deadline_ticks is not None
                              else None)))
            return False
        dl = (_NO_DEADLINE if req.deadline_ticks is None
              else self.metrics.ticks + req.deadline_ticks)
        seq = self._seq
        self._seq += 1
        heapq.heappush(self.queue, (getattr(req, "priority", 0), dl, seq))
        self._waiting[seq] = (req, self.metrics.ticks)
        if dl != _NO_DEADLINE:
            heapq.heappush(self._deadlines, (dl, seq))
        return True

    # -- one scheduling tick -------------------------------------------------
    def _expire_overdue(self) -> None:
        """Drop waiters whose admission deadline has already passed — the
        expiry heap orders by absolute deadline, so overdue entries are at
        its front regardless of priority reordering on the admission heap."""
        while self._deadlines and self._deadlines[0][0] < self.metrics.ticks:
            _, seq = heapq.heappop(self._deadlines)
            entry = self._waiting.pop(seq, None)
            if entry is None:                      # already admitted
                continue
            req, submitted = entry
            self.metrics.expired += 1
            self._emit_result(ServeResult(
                rid=req.rid, finish_reason="expired",
                wait_ticks=self.metrics.ticks - submitted,
                deadline_met=False))
        # keep `self.queue` truthiness meaning "live work waits": once
        # nothing is live the stale heap tail must not wedge drain loops
        while self.queue and self.queue[0][2] not in self._waiting:
            heapq.heappop(self.queue)

    def admit(self) -> int:
        """Fill free slots from the wait queue — at most `admit_width`
        requests (paged admission; a K-deep backend keeps its device batch
        width while holding (K-1+buckets)× slots) — in one batched
        backend.admit call. Returns the number admitted.

        Per-bucket accounting: a bucketed backend (one exposing
        ``bucket_of`` + ``bucket_admit_width``) admits at most
        ``bucket_admit_width`` requests *per bucket* per tick. A request
        whose bucket page is already full this tick is DEFERRED — left
        waiting, re-pushed with its original heap key — instead of ending
        the scan, so a starved bucket is never silently blocked behind a
        full sibling bucket (tests/test_serve_kdeep.py regression)."""
        self._expire_overdue()
        width = getattr(self.backend, "admit_width", None) \
            or self.backend.capacity
        bucket_of = getattr(self.backend, "bucket_of", None)
        bucket_width = getattr(self.backend, "bucket_admit_width", None)
        per_bucket: collections.Counter = collections.Counter()
        deferred: List[tuple] = []
        batch = []
        while self.queue and self.free and len(batch) < width:
            item = heapq.heappop(self.queue)
            seq = item[2]
            entry = self._waiting.get(seq)
            if entry is None:                      # stale (expired) entry
                continue
            req, submitted = entry
            cd = getattr(req, "completion_deadline_ticks", None)
            complete_by = (_NO_DEADLINE if cd is None else submitted + cd - 1)
            if complete_by < self.metrics.ticks:
                # completion already impossible (even a 1-tick service
                # misses): expire from the queue instead of burning a slot
                del self._waiting[seq]
                self.metrics.expired += 1
                self._emit_result(ServeResult(
                    rid=req.rid, finish_reason="expired",
                    wait_ticks=self.metrics.ticks - submitted,
                    deadline_met=False))
                continue
            if bucket_of is not None and bucket_width:
                b = bucket_of(req)
                if per_bucket[b] >= bucket_width:
                    deferred.append(item)          # full page: bucket waits,
                    continue                       # siblings keep admitting
                per_bucket[b] += 1
            del self._waiting[seq]
            slot = self.free.pop(0)
            batch.append((slot, req))
            self.active[slot] = _Active(
                req, admitted_tick=self.metrics.ticks,
                wait_ticks=self.metrics.ticks - submitted,
                complete_by=complete_by)
        for item in deferred:                      # original keys: ordering
            heapq.heappush(self.queue, item)       # is stable across ticks
        if batch:
            self.backend.admit(batch)
        return len(batch)

    def step_harvest(self, t0: Optional[float] = None) -> None:
        """One backend compute tick + emission ingest / completion. ``t0``
        lets tick() charge admission (batched prefill) to this tick's
        latency — EXPERIMENTS.md §Serve numbers are end-to-end."""
        if t0 is None:
            t0 = time.perf_counter()
        active_now = len(self.active)
        self.backend.step()
        tokens = images = 0
        for slot, ems in sorted(self.backend.harvest().items()):
            rec = self.active.get(slot)
            if rec is None:
                continue
            finish = None
            for em in ems:
                if em.kind == "tokens":         # bulk (device-side done-mask)
                    rec.tokens.extend(int(t) for t in em.payload)
                    tokens += len(em.payload)
                    if em.final:
                        finish = em.finish or "ok"
                        break
                    continue
                if em.kind != "token":          # payload wire (raw_head /
                    if em.final:                # detections / compose)
                        rec.payload = em.payload
                        images += 1
                        finish = em.finish or "ok"
                        break
                    continue
                tok = int(em.payload)
                rec.tokens.append(tok)
                tokens += 1
                sp = rec.req.sampling
                if tok in sp.stop_tokens:
                    finish = "stop"
                    break
                if len(rec.tokens) >= sp.max_new:
                    finish = "length"
                    break
            if finish:
                self._finish(slot, finish)
        # drop in-flight work that overran its completion deadline: it can
        # no longer finish inside its budget, so the slot recycles now and
        # any late backend emissions for it are ignored at harvest
        overrun = [slot for slot, rec in self.active.items()
                   if self.metrics.ticks >= rec.complete_by]
        for slot in overrun:
            self._drop_inflight(slot)
        # credit this tick's blocking device→host transfers (backends keep
        # running counters; the scheduler snapshots the step-path delta)
        syncs = getattr(self.backend, "host_syncs", None)
        if syncs is not None:
            self.metrics.host_syncs += syncs - self._synced
            self._synced = syncs
        sbytes = getattr(self.backend, "host_sync_bytes", None)
        if sbytes is not None:
            self.metrics.host_sync_bytes += sbytes - self._synced_bytes
            self._synced_bytes = sbytes
        csyncs = getattr(self.backend, "completion_syncs", None)
        if csyncs is not None:
            self.metrics.completion_syncs += csyncs - self._completion_synced
            self._completion_synced = csyncs
        self.metrics.record_tick(time.perf_counter() - t0, active_now,
                                 tokens=tokens, images=images,
                                 queued=len(self._waiting))

    def tick(self) -> None:
        t0 = time.perf_counter()
        self.admit()
        self.step_harvest(t0=t0)

    # -- driving -------------------------------------------------------------
    def run(self, requests=None) -> List[ServeResult]:
        """Serve until queue and pool drain; returns completion-ordered
        results (also kept on self.results unless a result_sink consumes
        them)."""
        for req in requests or ():
            self.submit(req)
        start = len(self.results)
        while self.queue or self.active:
            self.tick()
        return self.results[start:]

    def _finish(self, slot: int, reason: str) -> None:
        rec = self.active.pop(slot)
        dl = rec.req.deadline_ticks
        n_ticks = self.metrics.ticks - rec.admitted_tick + 1
        self._emit_result(ServeResult(
            rid=rec.req.rid, finish_reason=reason, tokens=rec.tokens,
            detections=rec.payload,
            n_ticks=n_ticks,
            wait_ticks=rec.wait_ticks,
            deadline_met=(None if dl is None else rec.wait_ticks <= dl)))
        self.metrics.completed += 1
        self.metrics.latency_ticks.append(rec.wait_ticks + n_ticks)
        self.backend.release(slot)
        self.free.append(slot)

    def _drop_inflight(self, slot: int) -> None:
        """Completion-deadline overrun: surface "expired" at harvest, count
        it as expired_inflight (NOT completed), recycle the slot."""
        rec = self.active.pop(slot)
        self._emit_result(ServeResult(
            rid=rec.req.rid, finish_reason="expired", tokens=rec.tokens,
            n_ticks=self.metrics.ticks - rec.admitted_tick + 1,
            wait_ticks=rec.wait_ticks,
            deadline_met=False))
        self.metrics.expired_inflight += 1
        self.backend.release(slot)
        self.free.append(slot)
