"""Request scheduler — paged admission, deadlines, stop conditions, metrics.

One `tick` = admit (expire overdue waiters, then fill free slots from the
bounded wait queue — at most `backend.admit_width` requests, one batched
backend.admit call) → backend.step (one fused compute tick; a streaming
backend dispatches tick t here and surfaces its results at tick t+1) →
harvest (ingest emissions in order, finish requests on stop-token / max_new
/ final-payload / bulk finish, recycle their slots).

Admission order is **FIFO-within-deadline**: the queue pops the earliest
(absolute admission deadline, arrival sequence) pair, so deadline-free
traffic stays strictly FIFO and deadlined requests overtake only
later-deadlined ones (EDF with FIFO tie-break). The wait queue is bounded
(`max_queue`): a submit into a full queue is rejected immediately
(finish_reason "rejected"); a waiter whose deadline passes before a slot
frees expires (finish_reason "expired"). Both surface as ServeResults so a
burst is always fully accounted: completed + rejected + expired = submitted.

Invariants:
  * a slot is in exactly one of {free, active} between ticks;
  * emissions for one slot are ingested in emission order, and everything
    after the finishing emission is dropped (a fused decode tick may
    overrun a request's stop condition by one token);
  * the wait queue drains to empty whenever the backend has capacity and
    requests have no (or generous) deadlines.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Dict, List, Optional

from repro.serve.api import (Backend, EngineMetrics, ServeRequest,
                             ServeResult)

_NO_DEADLINE = float("inf")


@dataclasses.dataclass
class _Active:
    req: ServeRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    payload: Optional[dict] = None
    admitted_tick: int = 0
    wait_ticks: int = 0


class Scheduler:
    def __init__(self, backend: Backend, *,
                 max_queue: Optional[int] = None,
                 metrics: Optional[EngineMetrics] = None):
        self.backend = backend
        self.metrics = metrics or EngineMetrics(capacity=backend.capacity)
        self.metrics.capacity = backend.capacity
        # heap of (abs_deadline, seq, submit_tick, req): FIFO within deadline
        self.queue: List[tuple] = []
        self.max_queue = max_queue
        self.free: List[int] = list(range(backend.capacity))
        self.active: Dict[int, _Active] = {}
        self.results: List[ServeResult] = []
        self._seq = 0
        # syncs already on the backend's counters (e.g. a warmup pass) are
        # not this scheduler's to credit
        self._synced = getattr(backend, "host_syncs", 0)
        self._synced_bytes = getattr(backend, "host_sync_bytes", 0)
        self._completion_synced = getattr(backend, "completion_syncs", 0)

    # -- submission ----------------------------------------------------------
    def submit(self, req: ServeRequest) -> bool:
        """Queue a request. Returns False (and surfaces a "rejected" result)
        when the bounded wait queue is full."""
        self.metrics.submitted += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.metrics.rejected += 1
            self.results.append(ServeResult(
                rid=req.rid, finish_reason="rejected",
                deadline_met=(False if req.deadline_ticks is not None
                              else None)))
            return False
        dl = (_NO_DEADLINE if req.deadline_ticks is None
              else self.metrics.ticks + req.deadline_ticks)
        heapq.heappush(self.queue, (dl, self._seq, self.metrics.ticks, req))
        self._seq += 1
        return True

    # -- one scheduling tick -------------------------------------------------
    def _expire_overdue(self) -> None:
        """Drop waiters whose admission deadline has already passed. The
        heap orders by deadline, so overdue entries are at the front."""
        while self.queue and self.queue[0][0] < self.metrics.ticks:
            _, _, submitted, req = heapq.heappop(self.queue)
            self.metrics.expired += 1
            self.results.append(ServeResult(
                rid=req.rid, finish_reason="expired",
                wait_ticks=self.metrics.ticks - submitted,
                deadline_met=False))

    def admit(self) -> int:
        """Fill free slots from the wait queue — at most `admit_width`
        requests (paged admission; a double-buffered backend keeps its
        device batch width while holding 2× slots) — in one batched
        backend.admit call. Returns the number admitted."""
        self._expire_overdue()
        width = getattr(self.backend, "admit_width", None) \
            or self.backend.capacity
        batch = []
        while self.queue and self.free and len(batch) < width:
            dl, _, submitted, req = heapq.heappop(self.queue)
            slot = self.free.pop(0)
            batch.append((slot, req))
            self.active[slot] = _Active(
                req, admitted_tick=self.metrics.ticks,
                wait_ticks=self.metrics.ticks - submitted)
        if batch:
            self.backend.admit(batch)
        return len(batch)

    def step_harvest(self, t0: Optional[float] = None) -> None:
        """One backend compute tick + emission ingest / completion. ``t0``
        lets tick() charge admission (batched prefill) to this tick's
        latency — EXPERIMENTS.md §Serve numbers are end-to-end."""
        if t0 is None:
            t0 = time.perf_counter()
        active_now = len(self.active)
        self.backend.step()
        tokens = images = 0
        for slot, ems in sorted(self.backend.harvest().items()):
            rec = self.active.get(slot)
            if rec is None:
                continue
            finish = None
            for em in ems:
                if em.tokens is not None:       # bulk (device-side done-mask)
                    rec.tokens.extend(int(t) for t in em.tokens)
                    tokens += len(em.tokens)
                    if em.final:
                        finish = em.finish or "ok"
                        break
                    continue
                if em.final:
                    rec.payload = em.payload
                    images += 1
                    finish = em.finish or "ok"
                    break
                rec.tokens.append(int(em.token))
                tokens += 1
                sp = rec.req.sampling
                if em.token in sp.stop_tokens:
                    finish = "stop"
                    break
                if len(rec.tokens) >= sp.max_new:
                    finish = "length"
                    break
            if finish:
                self._finish(slot, finish)
        # credit this tick's blocking device→host transfers (backends keep
        # running counters; the scheduler snapshots the step-path delta)
        syncs = getattr(self.backend, "host_syncs", None)
        if syncs is not None:
            self.metrics.host_syncs += syncs - self._synced
            self._synced = syncs
        sbytes = getattr(self.backend, "host_sync_bytes", None)
        if sbytes is not None:
            self.metrics.host_sync_bytes += sbytes - self._synced_bytes
            self._synced_bytes = sbytes
        csyncs = getattr(self.backend, "completion_syncs", None)
        if csyncs is not None:
            self.metrics.completion_syncs += csyncs - self._completion_synced
            self._completion_synced = csyncs
        self.metrics.record_tick(time.perf_counter() - t0, active_now,
                                 tokens=tokens, images=images,
                                 queued=len(self.queue))

    def tick(self) -> None:
        t0 = time.perf_counter()
        self.admit()
        self.step_harvest(t0=t0)

    # -- driving -------------------------------------------------------------
    def run(self, requests=None) -> List[ServeResult]:
        """Serve until queue and pool drain; returns completion-ordered
        results (also kept on self.results)."""
        for req in requests or ():
            self.submit(req)
        start = len(self.results)
        while self.queue or self.active:
            self.tick()
        return self.results[start:]

    def _finish(self, slot: int, reason: str) -> None:
        rec = self.active.pop(slot)
        dl = rec.req.deadline_ticks
        self.results.append(ServeResult(
            rid=rec.req.rid, finish_reason=reason, tokens=rec.tokens,
            detections=rec.payload,
            n_ticks=self.metrics.ticks - rec.admitted_tick + 1,
            wait_ticks=rec.wait_ticks,
            deadline_met=(None if dl is None else rec.wait_ticks <= dl)))
        self.metrics.completed += 1
        self.backend.release(slot)
        self.free.append(slot)
