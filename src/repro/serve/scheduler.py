"""Request scheduler — admission queueing, stop conditions, metrics.

One `tick` = admit (fill every free slot from the FIFO queue, one batched
backend.admit call) → backend.step (one fused compute tick) → harvest
(ingest emissions in order, finish requests on stop-token / max_new /
final-payload, recycle their slots).

Invariants:
  * a slot is in exactly one of {free, active} between ticks;
  * emissions for one slot are ingested in emission order, and everything
    after the finishing emission is dropped (a fused decode tick may
    overrun a request's stop condition by one token);
  * admission order is FIFO — results surface in completion order, rid-keyed.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

from repro.serve.api import (Backend, EngineMetrics, ServeRequest,
                             ServeResult)


@dataclasses.dataclass
class _Active:
    req: ServeRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    payload: Optional[dict] = None
    admitted_tick: int = 0


class Scheduler:
    def __init__(self, backend: Backend, *,
                 metrics: Optional[EngineMetrics] = None):
        self.backend = backend
        self.metrics = metrics or EngineMetrics(capacity=backend.capacity)
        self.metrics.capacity = backend.capacity
        self.queue: collections.deque = collections.deque()
        self.free: List[int] = list(range(backend.capacity))
        self.active: Dict[int, _Active] = {}
        self.results: List[ServeResult] = []

    # -- submission ----------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)
        self.metrics.submitted += 1

    # -- one scheduling tick -------------------------------------------------
    def admit(self) -> int:
        """Fill free slots from the queue; one batched backend.admit call.
        Returns the number of requests admitted."""
        batch = []
        while self.queue and self.free:
            slot = self.free.pop(0)
            req = self.queue.popleft()
            batch.append((slot, req))
            self.active[slot] = _Active(req, admitted_tick=self.metrics.ticks)
        if batch:
            self.backend.admit(batch)
        return len(batch)

    def step_harvest(self, t0: Optional[float] = None) -> None:
        """One backend compute tick + emission ingest / completion. ``t0``
        lets tick() charge admission (batched prefill) to this tick's
        latency — EXPERIMENTS.md §Serve numbers are end-to-end."""
        if t0 is None:
            t0 = time.perf_counter()
        active_now = len(self.active)
        self.backend.step()
        tokens = images = 0
        for slot, ems in sorted(self.backend.harvest().items()):
            rec = self.active.get(slot)
            if rec is None:
                continue
            finish = None
            for em in ems:
                if em.final:
                    rec.payload = em.payload
                    images += 1
                    finish = "ok"
                    break
                rec.tokens.append(int(em.token))
                tokens += 1
                sp = rec.req.sampling
                if em.token in sp.stop_tokens:
                    finish = "stop"
                    break
                if len(rec.tokens) >= sp.max_new:
                    finish = "length"
                    break
            if finish:
                self._finish(slot, finish)
        self.metrics.record_tick(time.perf_counter() - t0, active_now,
                                 tokens=tokens, images=images)

    def tick(self) -> None:
        t0 = time.perf_counter()
        self.admit()
        self.step_harvest(t0=t0)

    # -- driving -------------------------------------------------------------
    def run(self, requests=None) -> List[ServeResult]:
        """Serve until queue and pool drain; returns completion-ordered
        results (also kept on self.results)."""
        for req in requests or ():
            self.submit(req)
        start = len(self.results)
        while self.queue or self.active:
            self.tick()
        return self.results[start:]

    def _finish(self, slot: int, reason: str) -> None:
        rec = self.active.pop(slot)
        self.results.append(ServeResult(
            rid=rec.req.rid, finish_reason=reason, tokens=rec.tokens,
            detections=rec.payload,
            n_ticks=self.metrics.ticks - rec.admitted_tick + 1))
        self.metrics.completed += 1
        self.backend.release(slot)
        self.free.append(slot)
