"""serve v3 public API — one backend-agnostic, streaming request lifecycle.

A request enters as a `ServeRequest` (token prompt for LM decode, image for
W1A8 detection), waits in the scheduler's bounded queue, is assigned a pool
slot, flows through a `Backend` (admit / step / harvest), and leaves as a
`ServeResult`. The scheduler owns queueing, deadlines, stop conditions and
metrics; backends own only the model computation — so LM decode and YOLO
detection serve through the same loop (DESIGN.md §10–§11).

Backend protocol (one decode/inference tick per `step`):

    admit(assignments)   stage [(slot, request), ...] into the pool —
                         batched multi-row prefill for LMs, image staging
                         for detection. May already produce emissions.
    step()               advance every active slot by one fused tick. A
                         streaming backend may *dispatch* tick t's compute
                         here and only surface its results at tick t+1
                         (double buffering — harvest order still per slot).
    harvest()            drain {slot: [Emission, ...]} produced since the
                         last harvest, in emission order.
    release(slot)        scheduler returns a finished slot to the pool.

Optional backend attributes the scheduler honours:

    admit_width          max requests admitted per tick (paged admission;
                         default: capacity). A double-buffered backend
                         exposes capacity = 2·width so one batch can be in
                         flight while the next is staged.
    host_syncs           running count of blocking device→host transfers
                         on the per-tick step/harvest path (one batched
                         transfer event = 1). The scheduler snapshots the
                         delta into EngineMetrics each tick.
    completion_syncs     transfers that only happen when a request
                         finishes (e.g. the bulk token fetch of the
                         done-mask decode path) — boundary cost, kept out
                         of the steady-state per-tick number.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls (LM workloads; detection ignores them)."""
    max_new: int = 16
    temperature: float = 0.0          # 0 → greedy
    stop_tokens: Tuple[int, ...] = ()  # emitting any of these ends the request


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: Optional[Sequence[int]] = None      # LM workloads
    image: Optional[Any] = None                 # detection workloads
    # Static image geometry (H, W, C) — the bucketed multi-resolution
    # scheduler packs per-bucket batches off this field WITHOUT touching
    # the (possibly device-resident) pixels. Auto-filled from `image` at
    # construction when omitted.
    image_shape: Optional[Tuple[int, ...]] = None
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # Admission deadline, in scheduler ticks from submission: the request
    # must reach a pool slot within this many ticks or it expires in the
    # wait queue (finish_reason "expired"). None → wait forever (FIFO).
    deadline_ticks: Optional[int] = None
    # Completion deadline, in scheduler ticks from submission: once admitted,
    # the request must COMPLETE within this many ticks of its submit or the
    # scheduler drops the in-flight work at harvest (finish_reason "expired",
    # counted separately as expired_inflight). None → run to completion.
    completion_deadline_ticks: Optional[int] = None
    # Priority class: admission pops (priority, deadline, arrival-seq), so
    # LOWER numbers admit first; within one class ordering stays EDF with
    # FIFO tie-break. Default 0 keeps pre-priority traffic byte-identical.
    priority: int = 0

    def __post_init__(self) -> None:
        if self.image_shape is None and self.image is not None:
            self.image_shape = tuple(int(d) for d in np.shape(self.image))


@dataclasses.dataclass
class ServeResult:
    rid: int
    finish_reason: str              # "length"|"stop"|"ok"|"expired"|"rejected"
    tokens: List[int] = dataclasses.field(default_factory=list)
    detections: Optional[dict] = None           # boxes / scores / classes / raw
    n_ticks: int = 0                            # scheduler ticks slot was held
    wait_ticks: int = 0                         # ticks spent in the wait queue
    deadline_met: Optional[bool] = None         # None when no deadline was set


# The emission payload union — one `kind` tag per wire variant instead of
# parallel optional attributes (DESIGN.md §15):
#   "token"       payload: int            one host-checked LM decode token
#   "tokens"      payload: Tuple[int,...] bulk sequence (device done-mask)
#   "raw_head"    payload: dict           raw (G,G,75) head + NMS'd dets
#   "detections"  payload: dict           compact device-NMS detection set
#   "compose"     payload: dict           detect→LM hand-off (serve.compose)
EMISSION_KINDS = ("token", "tokens", "raw_head", "detections", "compose")


@dataclasses.dataclass
class Emission:
    """One unit of backend output for a slot: a `kind` tag plus the typed
    `payload` for that kind (see EMISSION_KINDS above).

    Host-side-checked LM decode emits one ``kind="token"`` per tick; a
    device-side-done backend instead emits nothing per tick and, when its
    done-mask lights up, one **bulk** ``kind="tokens"`` emission carrying
    the whole sequence plus the backend-decided `finish` reason — the async
    emission state of the streaming path (DESIGN.md §11). Detection emits a
    final ``"raw_head"`` (verification wire) or ``"detections"`` (compact
    device-NMS wire) payload dict — the dict is the wire format, so fleet
    bit-exactness checks compare it structurally, unchanged by this tag.
    `final=True` completes the request regardless of its sampling params.
    """
    kind: str = "token"
    payload: Any = None
    finish: Optional[str] = None                # backend-decided reason
    final: bool = False

    def __post_init__(self) -> None:
        if self.kind not in EMISSION_KINDS:
            raise ValueError(
                f"Emission.kind must be one of {EMISSION_KINDS}, "
                f"got {self.kind!r}")


class Backend(Protocol):
    capacity: int

    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        ...

    def step(self) -> None:
        ...

    def harvest(self) -> Dict[int, List[Emission]]:
        ...

    def release(self, slot: int) -> None:
        ...


@dataclasses.dataclass
class EngineMetrics:
    """Throughput / latency / occupancy / host-sync accounting, recorded per
    tick by the scheduler and summarised into BENCH_serve.json by
    launch/serve."""
    capacity: int = 0
    ticks: int = 0
    tokens: int = 0
    images: int = 0
    submitted: int = 0
    completed: int = 0
    rejected: int = 0                 # bounded wait queue was full at submit
    expired: int = 0                  # admission deadline passed while queued
    expired_inflight: int = 0         # completion deadline overran in a slot
    host_syncs: int = 0               # per-tick step/harvest-path transfers
    host_sync_bytes: int = 0          # bytes over those transfers
    completion_syncs: int = 0         # request-completion transfers
    tick_s: List[float] = dataclasses.field(default_factory=list)
    occupancy: List[float] = dataclasses.field(default_factory=list)
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    # end-to-end ticks (wait + service) per COMPLETED request — the per-
    # replica latency distribution the fleet SLO roll-up consumes
    latency_ticks: List[int] = dataclasses.field(default_factory=list)

    def record_tick(self, dt: float, active: int, *,
                    tokens: int = 0, images: int = 0,
                    queued: int = 0) -> None:
        self.ticks += 1
        self.tokens += tokens
        self.images += images
        self.tick_s.append(float(dt))
        self.occupancy.append(active / max(self.capacity, 1))
        self.queue_depth.append(int(queued))

    def summary(self) -> dict:
        wall = float(sum(self.tick_s))
        # An all-rejected (or never-ticked) window has NO recorded tick
        # latencies and NO completed requests: every quantile/mean below
        # must fall back to 0.0 instead of dividing by (or quantiling over)
        # an empty window — the summary is NaN-free by contract (regression:
        # tests/test_fleet.py::test_summary_nan_free_on_all_rejected_window).
        lat = np.asarray(self.tick_s) if self.tick_s else np.zeros(1)
        req_lat = (np.asarray(self.latency_ticks) if self.latency_ticks
                   else np.zeros(1))
        return {
            "ticks": self.ticks,
            "wall_s": wall,
            "requests_completed": self.completed,
            "requests_rejected": self.rejected,
            "requests_expired": self.expired,
            "requests_expired_inflight": self.expired_inflight,
            "requests_dropped": (self.rejected + self.expired
                                 + self.expired_inflight),
            "tokens": self.tokens,
            "images": self.images,
            "tok_per_s": self.tokens / wall if wall > 0 else 0.0,
            "img_per_s": self.images / wall if wall > 0 else 0.0,
            "tick_p50_ms": 1e3 * float(np.quantile(lat, 0.50)),
            "tick_p95_ms": 1e3 * float(np.quantile(lat, 0.95)),
            "latency_p50_ticks": float(np.quantile(req_lat, 0.50)),
            "latency_p95_ticks": float(np.quantile(req_lat, 0.95)),
            "batch_occupancy": (float(np.mean(self.occupancy))
                                if self.occupancy else 0.0),
            "host_syncs": self.host_syncs,
            "completion_syncs": self.completion_syncs,
            "host_syncs_per_tick": (self.host_syncs / self.ticks
                                    if self.ticks else 0.0),
            "host_sync_bytes_per_tick": (self.host_sync_bytes / self.ticks
                                         if self.ticks else 0.0),
            # per-sync payload width: comparable across overlap on/off and
            # across tick counts (drain ticks sync nothing)
            "host_sync_bytes_per_sync": (self.host_sync_bytes
                                         / self.host_syncs
                                         if self.host_syncs else 0.0),
            "queue_depth_max": (max(self.queue_depth)
                                if self.queue_depth else 0),
            "queue_depth_mean": (float(np.mean(self.queue_depth))
                                 if self.queue_depth else 0.0),
        }
