"""serve v2 public API — one backend-agnostic request lifecycle.

A request enters as a `ServeRequest` (token prompt for LM decode, image for
W1A8 detection), is assigned a pool slot by the `Scheduler`, flows through a
`Backend` (admit / step / harvest), and leaves as a `ServeResult`. The
scheduler owns queueing, stop conditions and metrics; backends own only the
model computation — so LM decode and YOLO detection serve through the same
loop (DESIGN.md §10).

Backend protocol (one decode/inference tick per `step`):

    admit(assignments)   stage [(slot, request), ...] into the pool —
                         batched multi-row prefill for LMs, image staging
                         for detection. May already produce emissions.
    step()               advance every active slot by one fused tick.
    harvest()            drain {slot: [Emission, ...]} produced since the
                         last harvest, in emission order.
    release(slot)        scheduler returns a finished slot to the pool.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls (LM workloads; detection ignores them)."""
    max_new: int = 16
    temperature: float = 0.0          # 0 → greedy
    stop_tokens: Tuple[int, ...] = ()  # emitting any of these ends the request


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: Optional[Sequence[int]] = None      # LM workloads
    image: Optional[Any] = None                 # detection workloads
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)


@dataclasses.dataclass
class ServeResult:
    rid: int
    finish_reason: str                          # "length" | "stop" | "ok"
    tokens: List[int] = dataclasses.field(default_factory=list)
    detections: Optional[dict] = None           # boxes / scores / classes / raw
    n_ticks: int = 0                            # scheduler ticks slot was held


@dataclasses.dataclass
class Emission:
    """One unit of backend output for a slot: a token (LM) or a final
    payload (detection). `final=True` completes the request regardless of
    its sampling params."""
    token: Optional[int] = None
    payload: Optional[dict] = None
    final: bool = False


class Backend(Protocol):
    capacity: int

    def admit(self, assignments: Sequence[Tuple[int, ServeRequest]]) -> None:
        ...

    def step(self) -> None:
        ...

    def harvest(self) -> Dict[int, List[Emission]]:
        ...

    def release(self, slot: int) -> None:
        ...


@dataclasses.dataclass
class EngineMetrics:
    """Throughput / latency / occupancy accounting, recorded per tick by the
    scheduler and summarised into BENCH_serve.json by launch/serve."""
    capacity: int = 0
    ticks: int = 0
    tokens: int = 0
    images: int = 0
    submitted: int = 0
    completed: int = 0
    tick_s: List[float] = dataclasses.field(default_factory=list)
    occupancy: List[float] = dataclasses.field(default_factory=list)

    def record_tick(self, dt: float, active: int, *,
                    tokens: int = 0, images: int = 0) -> None:
        self.ticks += 1
        self.tokens += tokens
        self.images += images
        self.tick_s.append(float(dt))
        self.occupancy.append(active / max(self.capacity, 1))

    def summary(self) -> dict:
        wall = float(sum(self.tick_s))
        lat = np.asarray(self.tick_s) if self.tick_s else np.zeros(1)
        return {
            "ticks": self.ticks,
            "wall_s": wall,
            "requests_completed": self.completed,
            "tokens": self.tokens,
            "images": self.images,
            "tok_per_s": self.tokens / wall if wall > 0 else 0.0,
            "img_per_s": self.images / wall if wall > 0 else 0.0,
            "tick_p50_ms": 1e3 * float(np.quantile(lat, 0.50)),
            "tick_p95_ms": 1e3 * float(np.quantile(lat, 0.95)),
            "batch_occupancy": (float(np.mean(self.occupancy))
                                if self.occupancy else 0.0),
        }
