"""Detect→LM composition — two workload classes on one tick loop (§15).

A `ComposeRequest` carries an image plus LM sampling params. Stage 1
serves the image through a detection `Scheduler`; when its final
detection emission completes, the detections are templated into an LM
prompt ("describe what was detected": a describe-task token, a
detection-count token, then one token per detected class) and handed off
as a ``kind="compose"`` Emission, which the pipeline re-admits to the LM
`Scheduler` as a stage-2 `ServeRequest` on the SAME tick loop — the
detect tick runs first, so a detection finishing at tick t starts LM
prefill at tick t, multiplexing both workload classes on one device pool.

Conservation is explicit: every submitted ComposeRequest surfaces exactly
one `ComposeResult` (stage-1 rejections/expiries short-circuit with a
``detect_*`` finish reason; stage-2 failures keep the detections and
report the LM reason), so ``lost == 0`` and no rid duplicates after a
drain — the compose-path analogue of the fleet conservation identity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.api import Emission, SamplingParams, ServeRequest
from repro.serve.scheduler import Scheduler

# Reserved prompt-template token ids (folded into the LM vocab below).
_TOK_DESCRIBE = 1          # "describe what was detected"
_TOK_COUNT0 = 2            # count tokens start here; classes follow


def detections_to_prompt(payload: Optional[dict], *, vocab: int,
                         max_classes: int = 8) -> Tuple[int, ...]:
    """Deterministic detection→prompt template.

    Accepts either detection wire form — compact device-NMS
    (boxes/scores/classes/valid) or raw-head (scores > 0 mark live rows) —
    and returns LM token ids: [DESCRIBE, COUNT(n), CLS(c_0), ...,
    CLS(c_{k-1})] with k ≤ max_classes, every id folded into [1, vocab).
    The same detections always template to the same prompt, so compose
    runs are replayable and the hand-off is bit-checkable.
    """
    if vocab < 4:
        raise ValueError(f"vocab too small for the template: {vocab}")
    if payload is None:
        n, classes = 0, []
    elif "valid" in payload:
        n = int(payload["valid"])
        classes = [int(c) for c in np.asarray(payload["classes"])[:n]]
    else:
        scores = np.asarray(payload["scores"]).reshape(-1)
        keep = np.flatnonzero(scores > 0)
        n = int(keep.size)
        classes = [int(c) for c in np.asarray(
            payload["classes"]).reshape(-1)[keep]]
    span = vocab - 1                    # ids land in [1, vocab)
    toks = [_TOK_DESCRIBE, 1 + (_TOK_COUNT0 - 1 + n) % span]
    toks += [1 + (_TOK_COUNT0 + int(c)) % span
             for c in classes[:max_classes]]
    return tuple(toks)


@dataclasses.dataclass
class ComposeRequest:
    rid: int
    image: Any
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    deadline_ticks: Optional[int] = None
    priority: int = 0


@dataclasses.dataclass
class ComposeResult:
    rid: int
    finish_reason: str          # LM reason, or "detect_<reason>" short-circuit
    detections: Optional[dict] = None
    prompt: Tuple[int, ...] = ()
    tokens: List[int] = dataclasses.field(default_factory=list)
    detect_ticks: int = 0       # stage-1 slot ticks (wait included)
    lm_ticks: int = 0           # stage-2 slot ticks (wait included)


class ComposePipeline:
    """Two schedulers, one tick loop: detection feeding LM description.

    ``detect_backend`` / ``lm_backend`` are ordinary serve backends; each
    gets its own Scheduler (own slot pool, deadlines, metrics) and both
    tick once per pipeline tick — detect first, so completions hand off to
    the LM without an idle tick in between. `handoffs` keeps the
    kind="compose" emissions in hand-off order for inspection/tests.
    """

    def __init__(self, detect_backend, lm_backend, *, vocab: int,
                 max_queue: Optional[int] = None,
                 max_classes: int = 8):
        self.vocab = int(vocab)
        self.max_classes = int(max_classes)
        self.detect = Scheduler(detect_backend, max_queue=max_queue,
                                result_sink=self._on_detect)
        self.lm = Scheduler(lm_backend, max_queue=max_queue,
                            result_sink=self._on_lm)
        self._meta: Dict[int, ComposeRequest] = {}   # rid → stage-1 request
        self._stage1: Dict[int, dict] = {}           # rid → hand-off record
        self.handoffs: List[Emission] = []
        self.results: List[ComposeResult] = []
        self.submitted = 0
        self.tick_no = 0

    # -- stage sinks ---------------------------------------------------------
    def _on_detect(self, res) -> None:
        meta = self._meta.pop(res.rid)
        if res.finish_reason != "ok":
            # stage-1 never reached a payload: surface the short-circuit
            # result now so the request is still conserved
            self.results.append(ComposeResult(
                rid=res.rid, finish_reason=f"detect_{res.finish_reason}",
                detections=res.detections,
                detect_ticks=res.wait_ticks + res.n_ticks))
            return
        prompt = detections_to_prompt(res.detections, vocab=self.vocab,
                                      max_classes=self.max_classes)
        handoff = Emission(kind="compose", final=True,
                           payload={"prompt": prompt,
                                    "detections": res.detections})
        self.handoffs.append(handoff)
        self._stage1[res.rid] = {
            "detections": res.detections, "prompt": prompt,
            "detect_ticks": res.wait_ticks + res.n_ticks}
        # re-admit on the same tick loop: the LM scheduler ticks after the
        # detect scheduler, so this request can prefill THIS tick
        self.lm.submit(ServeRequest(
            rid=res.rid, prompt=list(prompt), sampling=meta.sampling,
            priority=meta.priority))

    def _on_lm(self, res) -> None:
        rec = self._stage1.pop(res.rid)
        self.results.append(ComposeResult(
            rid=res.rid, finish_reason=res.finish_reason,
            detections=rec["detections"], prompt=rec["prompt"],
            tokens=list(res.tokens),
            detect_ticks=rec["detect_ticks"],
            lm_ticks=res.wait_ticks + res.n_ticks))

    # -- driving -------------------------------------------------------------
    def submit(self, req: ComposeRequest) -> bool:
        self.submitted += 1
        self._meta[req.rid] = req
        return self.detect.submit(ServeRequest(
            rid=req.rid, image=req.image,
            deadline_ticks=req.deadline_ticks, priority=req.priority))

    def tick(self) -> None:
        self.detect.tick()
        self.lm.tick()
        self.tick_no += 1

    @property
    def busy(self) -> bool:
        return bool(self.detect.queue or self.detect.active
                    or self.lm.queue or self.lm.active)

    def run(self, requests=None, guard: int = 10**6) -> List[ComposeResult]:
        for req in requests or ():
            self.submit(req)
        while self.busy:
            self.tick()
            guard -= 1
            if guard <= 0:
                raise RuntimeError("compose pipeline failed to drain")
        return self.results

    @property
    def lost(self) -> int:
        """Requests submitted but never surfaced (0 after a clean drain)."""
        return self.submitted - len(self.results)

    def summary(self) -> dict:
        rids = [r.rid for r in self.results]
        return {
            "submitted": self.submitted,
            "completed": len(self.results),
            "lost": self.lost,
            "duplicated": len(rids) - len(set(rids)),
            "handoffs": len(self.handoffs),
            "ticks": self.tick_no,
            "detect": self.detect.metrics.summary(),
            "lm": self.lm.metrics.summary(),
        }
