"""Serving engine: prefill + decode with slot-based continuous batching.

decode_step — one token for every active row against the stage-stacked
cache (same lax.scan structure as training, so the dry-run lowers the real
serving computation). Sliding-window archs (mixtral; gemma2 local layers)
use **ring KV caches** bounded by the window: long_500k decode for mixtral
keeps 4096 slots/layer instead of 524288 (128× cache memory, the
bounded-state property that makes the cell runnable — DESIGN.md §5).

Packed-W1A8 params (serve.packed.deploy_lm) drop weight HBM traffic 16×,
which is the dominant term of decode roofline (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mb
from repro.models.layers import (ModelConfig, embed, linear, norm, rope,
                                 unembed)
from repro.serve.cache import BIGPOS, init_cache  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# Attention with cache (decode: 1 token; ring writes via pos % L)
# ---------------------------------------------------------------------------

def _attn_decode(p, cfg: ModelConfig, x, kc, vc, pc, pos, *, mode,
                 window: int):
    b, _, d = x.shape
    hd, kvh = cfg.hd, cfg.num_kv_heads
    length = kc.shape[1]
    q = linear(p["wq"], x, mode).reshape(b, 1, cfg.num_heads, hd)
    k = linear(p["wk"], x, mode).reshape(b, 1, kvh, hd)
    v = linear(p["wv"], x, mode).reshape(b, 1, kvh, hd)
    q = rope(q, pos[:, None], theta=cfg.rope_theta,
             fraction=cfg.rope_fraction)
    k = rope(k, pos[:, None], theta=cfg.rope_theta,
             fraction=cfg.rope_fraction)
    slot = pos % length                                     # ring position
    bi = jnp.arange(b)
    kc = kc.at[bi, slot].set(k[:, 0])
    vc = vc.at[bi, slot].set(v[:, 0])
    pc = pc.at[bi, slot].set(pos)
    # GQA scores over the whole (ring) cache
    g = cfg.num_heads // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, kc) / jnp.sqrt(hd)
    logits = logits.astype(jnp.float32)
    if cfg.attn_softcap > 0:
        logits = cfg.attn_softcap * jnp.tanh(logits / cfg.attn_softcap)
    valid = pc <= pos[:, None]                              # causal+unwritten
    if window > 0:
        valid &= pc > (pos[:, None] - window)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vc).reshape(b, 1, -1)
    return linear(p["wo"], out, mode), kc, vc, pc


# ---------------------------------------------------------------------------
# decode_step / prefill
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, *, mode: str = "float",
                ctx=None) -> Tuple[jax.Array, dict]:
    """tokens (B, 1) → (logits (B, vocab), updated cache). O(1) per step for
    SSM/ring slots; O(cache_len) attention reads otherwise."""
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.period)]
    pos = cache["lengths"]
    x = embed(params["embed"], tokens)

    def stage(x, slot_and_cache):
        slots, caches = slot_and_cache
        new_caches = []
        for i, (mk, fk) in enumerate(kinds):
            slot, c = slots[i], caches[i]
            h = norm(slot["norm1"], x, cfg.norm_kind)
            if mk.startswith("attn"):
                window = 0
                if mk == "attn_local" or (cfg.sliding_window and
                                          not cfg.local_global):
                    window = cfg.sliding_window
                out, kc, vc, pc = _attn_decode(slot["attn"], cfg, h,
                                               c["k"], c["v"], c["pos"],
                                               pos, mode=mode, window=window)
                new_caches.append({"k": kc, "v": vc, "pos": pc})
            else:
                step_fn = (mb.mamba2_decode_step if cfg.ssm_kind == "mamba2"
                           else mb.mamba1_decode_step)
                out, nc = step_fn(slot["mamba"], cfg, h, c, mode)
                new_caches.append(nc)
            if cfg.post_norms:
                out = norm(slot["post_norm1"], out, cfg.norm_kind)
            x = x + out.astype(x.dtype)
            if fk != "none":
                h = norm(slot["norm2"], x, cfg.norm_kind)
                if fk == "moe":
                    from repro.models.transformer import _apply_moe
                    out = _apply_moe(slot["moe"], cfg, h, mode, ctx)
                else:
                    from repro.models.layers import mlp
                    out = mlp(slot["mlp"], cfg, h, mode)
                if cfg.post_norms:
                    out = norm(slot["post_norm2"], out, cfg.norm_kind)
                x = x + out.astype(x.dtype)
        return x, tuple(new_caches)

    x, new_slots = jax.lax.scan(stage, x, (params["slots"], cache["slots"]))
    x = norm(params["final_norm"], x, cfg.norm_kind)
    logits = unembed(params["embed"], cfg, x)[:, 0, :]
    return logits, {"slots": new_slots, "lengths": cache["lengths"] + 1}


def decode_step_donemask(cfg: ModelConfig, params: dict, cache: dict,
                         last_tok: jax.Array, tok_buf: jax.Array,
                         n_gen: jax.Array, done: jax.Array,
                         stop_tokens: jax.Array, max_new: jax.Array,
                         temp: jax.Array, key: jax.Array, *,
                         mode: str = "float", use_key: bool,
                         ctx=None) -> tuple:
    """One fused decode tick with **device-side stop detection** (the
    streaming-serving analogue of folding the eos test into the kernel:
    DESIGN.md §11). Sampling, the token-buffer append, and the
    stop-token / max_new tests all stay on device — the only thing a host
    must read back per tick is the (B,) bool ``done`` bitmask.

    State arrays (all device-resident, B = pool slots):
      last_tok (B,) int32        previous token per row (fed back each tick)
      tok_buf  (B, cap) int32    generated tokens, row r valid in [0, n_gen)
      n_gen    (B,) int32        tokens generated so far (incl. prefill tok)
      done     (B,) bool         True for finished *and* for vacant rows —
                                 a done row's buffers freeze while the fused
                                 step keeps advancing the full batch
      stop_tokens (B, S) int32   per-row stop set, -1 padding (never matches)
      max_new  (B,) int32        per-row length budget
      temp     (B,) f32          per-row temperature (0 → greedy)

    ``use_key`` is static: the host passes True only when some live row
    samples (temperature > 0), mirroring the host-side sampler's key
    discipline so both paths consume the PRNG stream identically —
    token-for-token equivalence is tested in tests/test_serve_stream.py.

    Returns (cache, last_tok, tok_buf, n_gen, done).
    """
    logits, cache = decode_step(cfg, params, cache, last_tok[:, None],
                                mode=mode, ctx=ctx)
    greedy = jnp.argmax(logits, -1)
    if use_key:
        # same expressions as LMBackend._sample so draws are bit-identical
        scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, -1)
        tok = jnp.where(temp > 0, sampled, greedy)
    else:
        tok = greedy
    tok = tok.astype(jnp.int32)
    live = ~done
    bi = jnp.arange(tok_buf.shape[0])
    idx = jnp.minimum(n_gen, tok_buf.shape[1] - 1)
    tok_buf = tok_buf.at[bi, idx].set(
        jnp.where(live, tok, tok_buf[bi, idx]))
    n_gen = n_gen + live.astype(jnp.int32)
    is_stop = jnp.any(tok[:, None] == stop_tokens, axis=1)
    done = done | (live & (is_stop | (n_gen >= max_new)))
    return cache, tok.astype(jnp.int32), tok_buf, n_gen, done


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            max_len: int, mode: str = "float",
            ctx=None) -> Tuple[jax.Array, dict]:
    """Process the prompt (B, S) and build the decode cache.

    Attention K/V for the prompt are written at positions [0, S); mamba
    slots carry the post-prompt recurrent state.
    """
    from repro.models.layers import attention
    kinds = [(cfg.mixer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.period)]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed(params["embed"], tokens)
    cache0 = init_cache(cfg, b, max_len, dtype=x.dtype)

    def stage(x, slot_and_cache):
        slots, caches = slot_and_cache
        new_caches = []
        for i, (mk, fk) in enumerate(kinds):
            slot, c = slots[i], caches[i]
            h = norm(slot["norm1"], x, cfg.norm_kind)
            if mk.startswith("attn"):
                window = 0
                if mk == "attn_local" or (cfg.sliding_window and
                                          not cfg.local_global):
                    window = cfg.sliding_window
                hd, kvh = cfg.hd, cfg.num_kv_heads
                k = linear(slot["attn"]["wk"], h, mode).reshape(b, s, kvh, hd)
                v = linear(slot["attn"]["wv"], h, mode).reshape(b, s, kvh, hd)
                kr = rope(k, positions, theta=cfg.rope_theta,
                          fraction=cfg.rope_fraction)
                out = attention(slot["attn"], cfg, h, mode=mode, causal=True,
                                window=window, positions=positions)
                length = c["k"].shape[1]
                take = min(s, length)
                src_from = s - take
                ring_pos = (jnp.arange(take) + src_from) % length
                kc = c["k"].at[:, ring_pos].set(kr[:, src_from:])
                vc = c["v"].at[:, ring_pos].set(v[:, src_from:])
                pc = c["pos"].at[:, ring_pos].set(
                    jnp.arange(src_from, s)[None, :])
                new_caches.append({"k": kc, "v": vc, "pos": pc})
            else:
                pre = (mb.mamba2_prefill if cfg.ssm_kind == "mamba2"
                       else mb.mamba1_prefill)
                out, nc = pre(slot["mamba"], cfg, h, mode=mode)
                new_caches.append(nc)
            if cfg.post_norms:
                out = norm(slot["post_norm1"], out, cfg.norm_kind)
            x = x + out
            if fk != "none":
                h = norm(slot["norm2"], x, cfg.norm_kind)
                if fk == "moe":
                    from repro.models.transformer import _apply_moe
                    out = _apply_moe(slot["moe"], cfg, h, mode, ctx)
                else:
                    from repro.models.layers import mlp
                    out = mlp(slot["mlp"], cfg, h, mode)
                if cfg.post_norms:
                    out = norm(slot["post_norm2"], out, cfg.norm_kind)
                x = x + out
        return x, tuple(new_caches)

    x, new_slots = jax.lax.scan(stage, x, (params["slots"], cache0["slots"]))
    x = norm(params["final_norm"], x, cfg.norm_kind)
    logits = unembed(params["embed"], cfg, x)[:, -1, :]
    return logits, {"slots": new_slots,
                    "lengths": jnp.full((b,), s, jnp.int32)}


def generate(cfg: ModelConfig, params: dict, prompts: jax.Array, *,
             max_new: int, max_len: int, mode: str = "float",
             temperature: float = 0.0, key: Optional[jax.Array] = None,
             ctx=None) -> jax.Array:
    """Greedy / temperature sampling: (B, S) prompts → (B, max_new) tokens."""
    logits, cache = prefill(cfg, params, prompts, max_len=max_len, mode=mode,
                            ctx=ctx)
    step_jit = jax.jit(functools.partial(decode_step, cfg, mode=mode,
                                         ctx=ctx))

    def sample(lg, k):
        if temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    nxt = sample(logits, key)
    for i in range(max_new):
        toks.append(nxt)
        if i == max_new - 1:
            break
        logits, cache = step_jit(params, cache, nxt[:, None])
        key = jax.random.fold_in(key, i)
        nxt = sample(logits, key)
    return jnp.stack(toks, axis=1)
