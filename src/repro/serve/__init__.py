"""Serving stack (v2): one backend-agnostic request lifecycle for LM decode
and W1A8 detection — `ServeRequest` → `Scheduler` → `Backend`
(admit / step / harvest) → `ServeResult`. Ring-aware caches, batched
multi-row prefill, packed-W1A8 deployment, SP long-context attention.
DESIGN.md §10."""
from repro.serve.api import (Backend, Emission,  # noqa: F401
                             EngineMetrics, SamplingParams, ServeRequest,
                             ServeResult)
from repro.serve.backends import DetectionBackend, LMBackend  # noqa: F401
from repro.serve.cache import cache_bytes, init_cache, merge_rows  # noqa: F401
from repro.serve.engine import (decode_step, generate,  # noqa: F401
                                prefill)
from repro.serve.packed import deploy_lm, packed_param_bytes  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
from repro.serve import fleet  # noqa: F401
from repro.serve import sp  # noqa: F401
from repro.serve.batching import Request, ServeEngine  # noqa: F401
