"""Serving stack (v3): one backend-agnostic request lifecycle for LM decode
and W1A8 detection — `ServeRequest` → `Scheduler` → `Backend`
(admit / step / harvest) → `ServeResult`. K-deep dispatch windows, bucketed
multi-resolution admission, ring-aware caches, batched multi-row prefill,
packed-W1A8 deployment, SP long-context attention, detect→LM composition.
DESIGN.md §10–§11, §15."""
from repro.serve.api import (EMISSION_KINDS, Backend, Emission,  # noqa: F401
                             EngineMetrics, SamplingParams, ServeRequest,
                             ServeResult)
from repro.serve.backends import (DetectionBackend,  # noqa: F401
                                  DispatchWindow, LMBackend)
from repro.serve.compose import (ComposePipeline, ComposeRequest,  # noqa: F401
                                 ComposeResult, detections_to_prompt)
from repro.serve.cache import cache_bytes, init_cache, merge_rows  # noqa: F401
from repro.serve.engine import (decode_step, generate,  # noqa: F401
                                prefill)
from repro.serve.packed import deploy_lm, packed_param_bytes  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
from repro.serve import fleet  # noqa: F401
from repro.serve import sp  # noqa: F401
from repro.serve.batching import Request, ServeEngine  # noqa: F401
