"""Serving stack: prefill/decode with ring-aware caches, slot-based request
batching, packed-W1A8 deployment, SP long-context attention."""
from repro.serve.engine import (decode_step, generate,  # noqa: F401
                                init_cache, prefill)
from repro.serve.packed import deploy_lm, packed_param_bytes  # noqa: F401
from repro.serve import sp  # noqa: F401
from repro.serve.batching import ServeEngine  # noqa: F401
