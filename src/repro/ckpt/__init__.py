"""Fault-tolerant checkpointing: atomic manifests, async writes, elastic
restore (reshard onto a different mesh at load)."""
from repro.ckpt.checkpoint import (latest_step, restore_checkpoint,  # noqa
                                   save_checkpoint, wait_for_async)
