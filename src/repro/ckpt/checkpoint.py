"""Checkpoint save/restore for sharded pytrees.

Fault-tolerance contract:
  * atomic: arrays land in ``<dir>/step_N.tmp/``, the manifest is written
    last, then the directory is renamed — a crash mid-write never corrupts
    the latest checkpoint (restore only reads committed directories);
  * async: ``save_checkpoint(..., async_=True)`` snapshots to host memory
    synchronously (device buffers freed for the next step) and writes on a
    background thread — training is blocked only for the device→host copy;
  * elastic: ``restore_checkpoint(..., shardings=...)`` re-lays arrays onto
    *any* target mesh (different device count than at save time) via
    ``jax.device_put`` of the assembled global arrays;
  * the data-pipeline cursor is just ``step`` (stateless sampling), stored in
    the manifest together with user metadata.

Multi-host note: on a real pod each host writes the shards it addresses
(`array.addressable_shards`) under `shard_<host>/`; this container is
single-host so every array is fully addressable and saved whole. The
manifest format already carries per-array shape/dtype so the multi-host
writer is a drop-in extension.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_PENDING: list = []


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    metadata: Optional[dict] = None,
                    async_: bool = False) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, paths, _ = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        entries = []
        for i, (arr, path) in enumerate(zip(host_leaves, paths)):
            np.save(os.path.join(tmp, f"{i:05d}.npy"), arr)
            entries.append({"index": i, "path": path,
                            "shape": list(arr.shape), "dtype": str(arr.dtype)})
        manifest = {"step": step, "arrays": entries,
                    "metadata": metadata or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # commit point

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()
    return final


def wait_for_async() -> None:
    while _PENDING:
        _PENDING.pop().join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, tree_like: Any, *,
                       shardings: Any = None):
    """Restore into the structure of ``tree_like``.

    shardings: optional pytree (same structure) of jax.sharding.Sharding —
    arrays are placed onto the target mesh (elastic restore).
    Returns (tree, metadata).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, paths, treedef = _flatten(tree_like)
    by_path = {e["path"]: e for e in manifest["arrays"]}
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for leaf, path, shd in zip(leaves, paths, shard_leaves):
        entry = by_path[path]
        arr = np.load(os.path.join(d, f"{entry['index']:05d}.npy"))
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{path}: ckpt {arr.shape} vs template {leaf.shape}"
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]
