"""Pure-JAX optimizers with the (init, update) pytree convention.

Each factory returns ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees of arrays → checkpointable and shardable like params
(optimizer state inherits each param's sharding rule).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def apply_updates(params, updates):
    return tmap(lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
                params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return tmap(lambda g: g * scale.astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    """lr: float or callable(step)->float."""

    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": tmap(zeros, params), "nu": tmap(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                  state["mu"], grads)
        nu = tmap(lambda v, g: b2 * v + (1 - b2) *
                  jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = tmap(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; the ≥398B-param option)
# ---------------------------------------------------------------------------

class _UpdPair(NamedTuple):
    """Unambiguous is_leaf marker (plain tuples collide with the model's
    `slots` tuple nodes when used as tree leaves)."""
    u: jax.Array
    v: dict


def adafactor(lr, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0):
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"v": tmap(one, params,
                          is_leaf=lambda x: isinstance(x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        beta = 1.0 - step.astype(jnp.float32) ** -decay

        def upd(g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(nvv + eps)
                nv = {"v": nvv}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return _UpdPair(-lr_t * u, nv)

        flat = tmap(upd, grads, state["v"])
        is_pair = lambda x: isinstance(x, _UpdPair)        # noqa: E731
        updates = tmap(lambda t: t.u, flat, is_leaf=is_pair)
        newv = tmap(lambda t: t.v, flat, is_leaf=is_pair)
        return updates, {"v": newv, "step": step}

    return init, update


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgdm(lr, *, momentum: float = 0.9):
    def init(params):
        return {"m": tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                          params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        m = tmap(lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                 state["m"], grads)
        updates = tmap(lambda m_: -lr_t * m_, m)
        return updates, {"m": m, "step": step}

    return init, update
