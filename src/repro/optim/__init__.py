"""Optimizers (pure JAX, no optax): AdamW, Adafactor, SGD-M + schedules.

Adafactor (factored second moment, no first moment by default) exists for
the ≥398B archs where AdamW's 8 bytes/param of state does not fit the pod —
see EXPERIMENTS.md §Dry-run memory notes.
"""
from repro.optim.optimizers import (adafactor, adamw,  # noqa: F401
                                    apply_updates, clip_by_global_norm, sgdm)
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
